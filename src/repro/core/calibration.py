"""Microbenchmark calibration of the performance model (paper §V-B).

The paper measures ARM-CL GEMM micro-benchmarks on the target board over a
grid of layer descriptors and fits Eq. 5 / Eq. 8 by linear regression.  We
do the honest analogue on this host: time single-stream f32 GEMMs with XLA
CPU for a sub-grid of the paper's parameter values

    I_w = I_h in {7, 14, 28, 56, 112}
    F_w = F_h in {1, 3, 5}
    I_d = F_d in {32, 64, 128}        Ofm in {32, 64, 128}

and fit the Eq. 5 coefficients.  Multi-core points for the alpha fit are
*synthesised* with a concave speedup law (measured thread scaling is not
controllable in-process; recorded as an adaptation in DESIGN.md §2).

Results are cached in ``calibration.json`` next to this file because the
measurement sweep takes tens of seconds.
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .descriptors import ConvDescriptor, GemmDims, conv_descriptor
from .perfmodel import MultiCoreModel, SingleCoreModel

_CACHE = os.path.join(os.path.dirname(__file__), "calibration.json")

# Sub-grid of the paper's §V-B microbenchmark sweep.
GRID_IHW = (7, 14, 28, 56, 112)
GRID_F = (1, 3, 5)
GRID_ID = (32, 64, 128)
GRID_OFM = (32, 64, 128)


def microbenchmark_grid() -> List[ConvDescriptor]:
    descs = []
    for ihw in GRID_IHW:
        for f in GRID_F:
            if f > ihw:
                continue
            for i_d in GRID_ID:
                for ofm in GRID_OFM:
                    descs.append(
                        conv_descriptor(
                            f"ub_{ihw}_{f}_{i_d}_{ofm}", ihw, i_d, f, ofm
                        )
                    )
    return descs


def _time_gemm(n: int, k: int, m: int, repeats: int = 3) -> float:
    """Median wall time of a single f32 [n,k]x[k,m] GEMM on the host."""
    import jax
    import jax.numpy as jnp

    a = jnp.asarray(np.random.default_rng(0).standard_normal((n, k)), jnp.float32)
    b = jnp.asarray(np.random.default_rng(1).standard_normal((k, m)), jnp.float32)
    f = jax.jit(lambda x, y: x @ y)
    f(a, b).block_until_ready()  # compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        f(a, b).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def measure_grid(
    descs: Optional[Sequence[ConvDescriptor]] = None,
) -> List[Tuple[Dict[str, int], float]]:
    descs = list(descs) if descs is not None else microbenchmark_grid()
    out = []
    for d in descs:
        g = d.gemm_dims()
        t = _time_gemm(g.N, g.K, g.M)
        out.append(({"N": g.N, "K": g.K, "M": g.M}, t))
    return out


def _synthetic_multicore_samples(
    single: SingleCoreModel,
    samples: Sequence[Tuple[GemmDims, float]],
    tile_size: int,
    cores: Sequence[int] = (1, 2, 3, 4),
    per_iter_dispatch_s: float = 2e-6,
    pool_overhead_s: float = 15e-6,
) -> List[Tuple[GemmDims, int, float]]:
    """Multi-threaded samples consistent with the Eq. 6-7 iteration model:
    a constant per-iteration dispatch cost plus a fixed thread-pool fork/
    join overhead.  The ceil() split of iterations over threads yields the
    concave speedup the paper observes (Fig. 11)."""
    out = []
    for dims, t1 in samples:
        n_it = max(1, math.ceil(dims.N / tile_size))
        t_iter = t1 / n_it + per_iter_dispatch_s
        for h in cores:
            iters_slowest = math.ceil(n_it / h)
            t = t_iter * iters_slowest + pool_overhead_s
            out.append((dims, h, t))
    return out


def calibrate(
    use_cache: bool = True,
    tile_size: int = 16,
) -> MultiCoreModel:
    """Fit the Eq. 5/8 model, measuring the host if no cache exists."""
    meas: List[Tuple[Dict[str, int], float]]
    if use_cache and os.path.exists(_CACHE):
        with open(_CACHE) as f:
            meas = [(s["dims"], s["t"]) for s in json.load(f)["samples"]]
    else:
        meas = measure_grid()
        with open(_CACHE, "w") as f:
            json.dump(
                {"samples": [{"dims": d, "t": t} for d, t in meas]}, f, indent=1
            )
    samples = [(GemmDims(**d), t) for d, t in meas]
    single = SingleCoreModel.fit(samples)
    multi_samples = _synthetic_multicore_samples(single, samples, tile_size)
    return MultiCoreModel.fit(single, multi_samples, tile_size=tile_size)


# ---------------------------------------------------------------------------
# Online correction (the adaptive runtime's calibration primitive)
# ---------------------------------------------------------------------------
#
# The offline fit above produces the Eq. 5/8 *prior*; the serving runtime
# observes actual per-stage service times (metrics.py) and folds them back
# into the time matrix as per-core-type multiplicative corrections — the
# minimal model that captures the paper's dominant error mode (Table III:
# whole-cluster mis-prediction, e.g. DVFS or contention slowing one cluster
# uniformly).  See serving/adaptive.py for the EWMA estimator.

def apply_correction(
    T: Sequence[Dict], correction: Dict[str, float]
) -> List[Dict]:
    """Scale a time matrix by per-core-type factors: ``T'[l][(ct, n)] =
    T[l][(ct, n)] * correction.get(ct, 1.0)``.  Returns a new matrix."""
    return [
        {stage: t * correction.get(stage[0], 1.0) for stage, t in row.items()}
        for row in T
    ]


def scale_core_type(
    T: Sequence[Dict], core_type: str, factor: float
) -> List[Dict]:
    """A drifted copy of ``T`` with one cluster uniformly ``factor`` x
    slower — the synthetic-drift injector used by tests and benchmarks."""
    return apply_correction(T, {core_type: factor})


def synthetic_model(tile_size: int = 16) -> MultiCoreModel:
    """A deterministic analytical model (no host measurement) for tests and
    CI: times follow a two-term roofline ``max(flops/F, bytes/B)`` with a
    fixed per-call overhead, then Eq. 5 is fitted to it."""
    F, B, C = 2.0e9, 8.0e9, 30e-6  # flops/s, bytes/s, fixed cost (1 ARM core)
    descs = microbenchmark_grid()
    samples = []
    for d in descs:
        g = d.gemm_dims()
        t = max(g.flops / F, g.bytes_touched() / B) + C
        samples.append((g, t))
    single = SingleCoreModel.fit(samples)
    multi = _synthetic_multicore_samples(single, samples, tile_size)
    return MultiCoreModel.fit(single, multi, tile_size=tile_size)
