"""Pipe-it on TPU pods: the paper's scheduling algorithms applied to the
model axis of a pod.

Mapping (DESIGN.md §2): a pipeline stage is a GROUP of chips on the model
axis; intra-stage parallelism is tensor-parallel sharding (the paper's
kernel-level split), and the stage boundary moves one activation tensor
over ICI (the CCI analogue).  "Heterogeneity" is group size: a 8-chip
stage processes a layer faster than a 2-chip stage, but with concave
returns — every TP layer pays an all-reduce whose cost grows with group
size, exactly the concavity (paper Fig. 11) that makes merge_stage's
Eq. 14 stop rule meaningful.

The per-layer cost model plays the role of Eq. 5/8: analytic roofline
terms per layer on an n-chip group,

    t_l(n) = max(flops_l / (n * PEAK), bytes_l / (n * HBM))
             + ar_bytes(n) / ICI_BW          (0 when n == 1)

with ar_bytes the ring all-reduce traffic of the layer's TP collectives.
The same ``pipe_it_search`` then picks stage groups + layer ranges.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from ..configs.shapes import InputShape
from ..models.config import ModelConfig
from .dse import pipe_it_search
from .pipeline import PipelinePlan, TimeMatrix
from .platform import CoreType, HeteroPlatform, StageConfig

PEAK = 197e12  # bf16 flop/s per chip
HBM = 819e9  # bytes/s
ICI = 50e9  # bytes/s per link
HANDOFF_S = 2e-6  # stage-boundary activation send latency


@dataclasses.dataclass(frozen=True)
class TpuLayerCost:
    name: str
    flops_per_token: float  # forward flops per token
    weight_bytes: float  # parameter bytes the layer streams per step
    act_bytes_per_token: float  # residual-stream activation bytes
    n_collectives: int  # TP all-reduces per layer (attn out, ffn out, ...)


def layer_costs(cfg: ModelConfig, seq_len: int) -> List[TpuLayerCost]:
    """Analytic per-layer costs from the config (the Eq. 3-4 analogue:
    statically-available descriptors -> cost terms)."""
    d = cfg.d_model
    dh = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    out: List[TpuLayerCost] = []
    act = d * 2  # bf16 residual stream per token

    for li in range(cfg.n_layers):
        attn_p = d * (h + 2 * kv + h) * dh  # wq, wk, wv, wo
        window = cfg.sliding_window or seq_len
        if cfg.full_attn_layers and li in cfg.full_attn_layers:
            window = seq_len
        score = 2 * min(window, seq_len) * h * dh  # qk^T + pv per token
        if cfg.block_kind == "xlstm":
            # mLSTM: qkv + gates + out projections; state update O(N*P)
            p = d * d * 5
            fl = 2 * p + 2 * dh * (dh + 1) * cfg.n_heads
            out.append(TpuLayerCost(f"l{li}", fl, p * 2, act, 2))
            continue
        if cfg.block_kind == "hymba":
            mamba_p = d * 2 * cfg.d_inner + cfg.d_inner * (d + 2 * cfg.ssm_state)
            ffn_p = d * cfg.d_ff * (3 if cfg.glu else 2)
            p = attn_p + mamba_p + ffn_p
            fl = 2 * p + score + 2 * cfg.d_inner * cfg.ssm_state
            out.append(TpuLayerCost(f"l{li}", fl, p * 2, act, 3))
            continue
        if cfg.n_experts and li >= cfg.first_dense_layers:
            expert_p = cfg.d_model * cfg.d_ff * (3 if cfg.glu else 2)
            active = expert_p * cfg.top_k + expert_p * cfg.n_shared_experts
            weights = expert_p * cfg.n_experts + expert_p * cfg.n_shared_experts
            p_flops = attn_p + active
            p_bytes = (attn_p + weights) * 2
            fl = 2 * p_flops + score
            out.append(TpuLayerCost(f"l{li}", fl, p_bytes, act, 3))
            continue
        ffn_p = d * cfg.d_ff * (3 if cfg.glu else 2)
        p = attn_p + ffn_p
        fl = 2 * p + score
        out.append(TpuLayerCost(f"l{li}", fl, p * 2, act, 2))
    return out


def tpu_platform(n_chips: int = 16) -> HeteroPlatform:
    """One homogeneous chip type; stage capability = group size."""
    return HeteroPlatform(
        name=f"tpu-pod-axis-{n_chips}",
        core_types=(CoreType("c", n_chips, 1.0),),
        boundary_bytes_per_s=ICI,
        boundary_latency_s=HANDOFF_S,
    )


def stage_time(cost: TpuLayerCost, n: int, tokens_per_step: float) -> float:
    compute = cost.flops_per_token * tokens_per_step / (n * PEAK)
    memory = cost.weight_bytes / (n * HBM)
    t = max(compute, memory)
    if n > 1:
        # ring all-reduce of the layer output: 2 (n-1)/n * bytes over ICI
        ar = cost.n_collectives * 2 * (n - 1) / n * (
            cost.act_bytes_per_token * tokens_per_step
        )
        t += ar / ICI
    return t


def time_matrix(
    costs: Sequence[TpuLayerCost], n_chips: int, tokens_per_step: float
) -> TimeMatrix:
    return [
        {("c", n): stage_time(c, n, tokens_per_step) for n in range(1, n_chips + 1)}
        for c in costs
    ]


def plan_stages(
    cfg: ModelConfig,
    shape: InputShape,
    n_chips: int = 16,
    mode: str = "best",
) -> Tuple[PipelinePlan, Dict[str, float]]:
    """Run the paper's DSE over the pod's model axis.

    tokens_per_step: decode -> batch tokens; train/prefill -> microbatch
    tokens in flight per pipeline step (batch * seq / data-parallel — the
    data axis is orthogonal and already sharded, so per model-axis group
    it is batch/data * seq tokens)."""
    if shape.kind == "decode":
        tokens = shape.global_batch / 16  # per data shard
    else:
        tokens = shape.global_batch * shape.seq_len / 16
    costs = layer_costs(cfg, shape.seq_len)
    T = time_matrix(costs, n_chips, tokens)
    plat = tpu_platform(n_chips)
    plan = pipe_it_search(cfg.n_layers, plat, T, mode=mode)
    tp_pipe = plan.throughput(T)

    # baseline: pure tensor-parallel over all chips (the "kernel-level"
    # strategy — one stage, every layer split 16 ways)
    from .pipeline import Pipeline, PipelinePlan as PP

    base = PP(Pipeline((("c", n_chips),)), (tuple(range(cfg.n_layers)),))
    tp_base = base.throughput(T)
    return plan, {
        "pipeline_steps_per_s": tp_pipe,
        "tp_baseline_steps_per_s": tp_base,
        "gain": tp_pipe / tp_base - 1,
        "tokens_per_step": tokens,
    }
