"""Discrete-event simulator for a layer-level pipeline (paper §III-B).

Validates the steady-state throughput formula (Eq. 12) including pipeline
fill/drain and inter-stage activation transfer over the cluster boundary
(the CCI on big.LITTLE, an ICI hop between TPU stage groups).

Model: each stage is a server with a single-slot output register; image z
can start on stage i once (a) stage i finished image z-1 and (b) stage i-1
has delivered image z (service + boundary transfer when the stage's core
type differs — same-cluster handoffs stay inside the shared L2 and are
free, which is precisely the paper's motivation for layer-level splits).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, List, Optional, Sequence

from .pipeline import PipelinePlan, TimeMatrix
from .platform import HeteroPlatform
from .queueing import empirical_percentile


class SimulatedClock:
    """A virtual monotone clock for deterministic control-loop runs.

    The adaptive runtime (serving/adaptive.py) periodically samples a
    clock; under test the discrete-event simulator advances this one by
    each round's makespan instead of waiting wall time, so every run of
    the calibrate -> detect -> re-plan loop is exactly reproducible.
    The interface is the subset of ``time`` the runtime uses: ``now()``
    (a perf_counter analogue) and ``sleep()`` (which simply advances).
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            return self._now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("clock cannot go backwards")
        with self._lock:
            self._now += dt
            return self._now

    def sleep(self, dt: float) -> None:
        self.advance(max(dt, 0.0))


@dataclasses.dataclass
class SimResult:
    makespan_s: float
    steady_throughput: float  # from the last half of the stream
    overall_throughput: float  # n_images / makespan
    stage_busy_s: List[float]
    finish_times: List[float]
    # DVFS / power accounting (0.0 when the platform has no power model or
    # no stage_freqs were assigned): active energy over the whole stream
    # and its average over the makespan — the quantities power caps and
    # the throughput/watt objective are stated in.
    energy_j: float = 0.0
    avg_power_w: float = 0.0
    # Open-loop accounting (present for closed-loop runs too: with all
    # arrivals at t=0 the "latency" of image z includes waiting behind its
    # z-1 predecessors, i.e. the saturation sojourn time).
    latencies_s: List[float] = dataclasses.field(default_factory=list)
    latency_p50_s: float = 0.0
    latency_p95_s: float = 0.0
    latency_p99_s: float = 0.0
    shed: int = 0  # arrivals rejected by the admission callback
    # stage_free at the end of the run: the queue state to carry into the
    # next simulation window (``simulate(initial_free=...)``) so windowed
    # control loops see backlogs survive across control decisions.
    stage_free_s: List[float] = dataclasses.field(default_factory=list)
    # Fault injection accounting (``simulate(faults=...)``): scheduled
    # events that fired and the total downtime (backoffs, restarts,
    # stalls) they added on top of useful service time.
    fault_events: int = 0
    fault_delay_s: float = 0.0


def simulate(
    plan: PipelinePlan,
    T: TimeMatrix,
    platform: HeteroPlatform,
    n_images: int = 50,
    boundary_bytes: Optional[Sequence[int]] = None,
    stage_freqs: Optional[Sequence[Optional[float]]] = None,
    arrival_s: Optional[Sequence[float]] = None,
    initial_free: Optional[Sequence[float]] = None,
    admit: Optional[Callable[[float, float], bool]] = None,
    faults=None,
) -> SimResult:
    """Simulate ``n_images`` flowing through the pipeline.

    ``boundary_bytes[i]`` is the activation size crossing the boundary
    between stage i and i+1 (0 => same cluster / negligible).

    ``stage_freqs`` assigns each stage an OPP of its cluster (see
    ``platform.freq_levels``): service times scale by ``(f_max/f)^kappa``
    and each stage's busy time is charged the cluster's active power at
    that OPP, filling ``SimResult.energy_j``/``avg_power_w`` — the
    simulator-side ground truth the power-aware DSE is validated against.

    ``arrival_s`` switches the run open-loop: an ascending sequence of
    absolute arrival times (e.g. ``serving.loadgen.poisson_trace().times``)
    replaces the closed-loop "enter as soon as stage 0 frees up" rule, and
    ``SimResult`` reports per-image latency (finish - arrival) percentiles
    — the ground truth ``core.queueing.predict_latency`` is validated
    against.  ``n_images`` is ignored when a trace is given.

    ``initial_free`` seeds per-stage busy-until times (from a previous
    window's ``stage_free_s``) so windowed control loops carry queue state.
    ``admit(arrival_time, predicted_wait_s)`` is consulted per arrival;
    returning False sheds the image (counted in ``SimResult.shed``) —
    the hook the queue-aware admission controller plugs into.

    ``faults`` injects a deterministic fault schedule: a
    ``serving.faults.FaultPlan`` (or a pre-built ``FaultInjector`` —
    duck-typed on ``.injector()``/``.sim_delay()`` so ``core`` never
    imports the serving package).  Each stage invocation consults the
    injector and pays the recovery delay its policy implies (retry
    backoffs, restart + re-dispatch, stall detection) — the same
    per-stage invocation ordinals the live wrapped stage fns consume,
    so a scenario reproduces identically in both worlds.  No image is
    ever lost: faults only delay; ``SimResult.fault_events`` /
    ``fault_delay_s`` account for them.
    """
    p = plan.pipeline.p
    service = plan.stage_times(T)
    stage_power = [0.0] * p
    if stage_freqs is not None:
        if len(stage_freqs) != p:
            raise ValueError(f"{len(stage_freqs)} stage_freqs for {p} stages")
        service = [
            t * platform.freq_scale(stage[0], f)
            for t, stage, f in zip(service, plan.pipeline.stages, stage_freqs)
        ]
        stage_power = [
            platform.active_power_w(stage[0], stage[1], f)
            for stage, f in zip(plan.pipeline.stages, stage_freqs)
        ]
    if boundary_bytes is None:
        boundary_bytes = [0] * max(p - 1, 0)

    transfer = []
    for i in range(p - 1):
        (ta, _), (tb, _) = plan.pipeline.stages[i], plan.pipeline.stages[i + 1]
        nbytes = boundary_bytes[i]
        # Same-cluster handoff stays in the shared L2: no CCI crossing.
        transfer.append(platform.transfer_time(nbytes) if ta != tb and nbytes else 0.0)

    if arrival_s is None:
        # Closed loop: every image is already waiting at t=0; image z
        # enters stage 0 the moment it frees up (start = max(0, free)).
        arrivals: Sequence[float] = [0.0] * n_images
    else:
        arrivals = list(arrival_s)
        for a, b in zip(arrivals, arrivals[1:]):
            if b < a:
                raise ValueError("arrival_s must be ascending")
        if arrivals and arrivals[0] < 0.0:
            raise ValueError("arrival times must be >= 0")

    # stage_free[i] = time stage i finishes its current image
    if initial_free is not None:
        if len(initial_free) != p:
            raise ValueError(f"{len(initial_free)} initial_free for {p} stages")
        stage_free = [float(x) for x in initial_free]
    else:
        stage_free = [0.0] * p
    finish: List[float] = []
    latencies: List[float] = []
    busy = [0.0] * p
    shed = 0
    # Duck-typed fault schedule: FaultPlan grows a fresh injector per
    # run; a caller-built injector is used as-is (shared counters).
    inj = None
    if faults is not None:
        inj = faults.injector() if hasattr(faults, "injector") else faults
    fault_delay = 0.0

    for a in arrivals:
        if admit is not None and not admit(a, max(stage_free[0] - a, 0.0)):
            shed += 1
            continue
        t = a
        for i in range(p):
            extra = inj.sim_delay(i) if inj is not None else 0.0
            start = max(t, stage_free[i])
            # Injected downtime (retries, restart + re-dispatch, stalls)
            # extends this image's occupancy of the stage but is not
            # useful busy time (occupancy/energy stay service-based).
            end = start + service[i] + extra
            busy[i] += service[i]
            fault_delay += extra
            stage_free[i] = end
            t = end + (transfer[i] if i < p - 1 else 0.0)
        finish.append(t)
        latencies.append(t - a)

    n_done = len(finish)
    makespan = finish[-1] if finish else 0.0
    half = max(1, n_done // 2)
    if n_done > half:
        steady = (n_done - half) / max(finish[-1] - finish[half - 1], 1e-12)
    else:
        steady = n_done / max(makespan, 1e-12)
    energy = sum(pw * b for pw, b in zip(stage_power, busy))
    return SimResult(
        makespan_s=makespan,
        steady_throughput=steady,
        overall_throughput=n_done / max(makespan, 1e-12),
        stage_busy_s=busy,
        finish_times=finish,
        energy_j=energy,
        avg_power_w=energy / max(makespan, 1e-12),
        latencies_s=latencies,
        latency_p50_s=empirical_percentile(latencies, 50.0),
        latency_p95_s=empirical_percentile(latencies, 95.0),
        latency_p99_s=empirical_percentile(latencies, 99.0),
        shed=shed,
        stage_free_s=list(stage_free),
        fault_events=inj.total_fired if inj is not None else 0,
        fault_delay_s=fault_delay,
    )
