"""Discrete-event simulator for a layer-level pipeline (paper §III-B).

Validates the steady-state throughput formula (Eq. 12) including pipeline
fill/drain and inter-stage activation transfer over the cluster boundary
(the CCI on big.LITTLE, an ICI hop between TPU stage groups).

Model: each stage is a server with a single-slot output register; image z
can start on stage i once (a) stage i finished image z-1 and (b) stage i-1
has delivered image z (service + boundary transfer when the stage's core
type differs — same-cluster handoffs stay inside the shared L2 and are
free, which is precisely the paper's motivation for layer-level splits).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import List, Optional, Sequence

from .pipeline import PipelinePlan, TimeMatrix
from .platform import HeteroPlatform


class SimulatedClock:
    """A virtual monotone clock for deterministic control-loop runs.

    The adaptive runtime (serving/adaptive.py) periodically samples a
    clock; under test the discrete-event simulator advances this one by
    each round's makespan instead of waiting wall time, so every run of
    the calibrate -> detect -> re-plan loop is exactly reproducible.
    The interface is the subset of ``time`` the runtime uses: ``now()``
    (a perf_counter analogue) and ``sleep()`` (which simply advances).
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            return self._now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("clock cannot go backwards")
        with self._lock:
            self._now += dt
            return self._now

    def sleep(self, dt: float) -> None:
        self.advance(max(dt, 0.0))


@dataclasses.dataclass
class SimResult:
    makespan_s: float
    steady_throughput: float  # from the last half of the stream
    overall_throughput: float  # n_images / makespan
    stage_busy_s: List[float]
    finish_times: List[float]
    # DVFS / power accounting (0.0 when the platform has no power model or
    # no stage_freqs were assigned): active energy over the whole stream
    # and its average over the makespan — the quantities power caps and
    # the throughput/watt objective are stated in.
    energy_j: float = 0.0
    avg_power_w: float = 0.0


def simulate(
    plan: PipelinePlan,
    T: TimeMatrix,
    platform: HeteroPlatform,
    n_images: int = 50,
    boundary_bytes: Optional[Sequence[int]] = None,
    stage_freqs: Optional[Sequence[Optional[float]]] = None,
) -> SimResult:
    """Simulate ``n_images`` flowing through the pipeline.

    ``boundary_bytes[i]`` is the activation size crossing the boundary
    between stage i and i+1 (0 => same cluster / negligible).

    ``stage_freqs`` assigns each stage an OPP of its cluster (see
    ``platform.freq_levels``): service times scale by ``(f_max/f)^kappa``
    and each stage's busy time is charged the cluster's active power at
    that OPP, filling ``SimResult.energy_j``/``avg_power_w`` — the
    simulator-side ground truth the power-aware DSE is validated against.
    """
    p = plan.pipeline.p
    service = plan.stage_times(T)
    stage_power = [0.0] * p
    if stage_freqs is not None:
        if len(stage_freqs) != p:
            raise ValueError(f"{len(stage_freqs)} stage_freqs for {p} stages")
        service = [
            t * platform.freq_scale(stage[0], f)
            for t, stage, f in zip(service, plan.pipeline.stages, stage_freqs)
        ]
        stage_power = [
            platform.active_power_w(stage[0], stage[1], f)
            for stage, f in zip(plan.pipeline.stages, stage_freqs)
        ]
    if boundary_bytes is None:
        boundary_bytes = [0] * max(p - 1, 0)

    transfer = []
    for i in range(p - 1):
        (ta, _), (tb, _) = plan.pipeline.stages[i], plan.pipeline.stages[i + 1]
        nbytes = boundary_bytes[i]
        # Same-cluster handoff stays in the shared L2: no CCI crossing.
        transfer.append(platform.transfer_time(nbytes) if ta != tb and nbytes else 0.0)

    # done[i] = time stage i finishes its current image
    stage_free = [0.0] * p
    arrive = [0.0] * p  # arrival time of the current image at stage i
    finish: List[float] = []
    busy = [0.0] * p

    for _ in range(n_images):
        t = 0.0  # image enters stage 0 as soon as the stage frees up
        for i in range(p):
            start = max(t, stage_free[i])
            end = start + service[i]
            busy[i] += service[i]
            stage_free[i] = end
            t = end + (transfer[i] if i < p - 1 else 0.0)
        finish.append(t)

    makespan = finish[-1]
    half = max(1, n_images // 2)
    if n_images > half:
        steady = (n_images - half) / max(finish[-1] - finish[half - 1], 1e-12)
    else:
        steady = n_images / max(makespan, 1e-12)
    energy = sum(pw * b for pw, b in zip(stage_power, busy))
    return SimResult(
        makespan_s=makespan,
        steady_throughput=steady,
        overall_throughput=n_images / max(makespan, 1e-12),
        stage_busy_s=busy,
        finish_times=finish,
        energy_j=energy,
        avg_power_w=energy / max(makespan, 1e-12),
    )
