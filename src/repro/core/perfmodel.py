"""Layer-level performance estimation (paper §V, Eqs. 5-8).

Single-core model (Eq. 5):

    T = b1*N + b2*K + b3*M + b4*NK + b5*KM + b6*NM + b7*NKM + b8

Multi-core model (Eqs. 6-8) over ARM-CL's row-tiled GEMM: the image matrix
is split along N into ``n_iter = ceil(N / ts)`` iterations dispatched over
H threads:

    T_iter  = (T - a1) / n_iter + a2                       (Eq. 6)
    T_multi = max_t (T_iter * iter_t) + a3                 (Eq. 7)
            = (T - a1)/H + a2 * N/(ts*H) + a3   (equal split, Eq. 8)

The coefficients are fitted by linear least squares on microbenchmark
measurements (``core/calibration.py``).  Heterogeneity enters through the
platform's per-core-type ``speed`` factor: a core of speed ``v`` executes
the same iteration stream ``1/v`` times slower.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .descriptors import ConvDescriptor, GemmDims
from .platform import HeteroPlatform, StageConfig

# DVFS-extended time matrix: T[layer][(core_type, count, freq_hz)] — the
# (layer, config, freq) form; freq None marks a fixed-clock cluster.
FreqTimeMatrix = List[Dict[Tuple[str, int, Optional[float]], float]]


def _features(dims: GemmDims) -> np.ndarray:
    n, k, m = float(dims.N), float(dims.K), float(dims.M)
    return np.array([n, k, m, n * k, k * m, n * m, n * m * k, 1.0])


@dataclasses.dataclass
class SingleCoreModel:
    """Eq. 5 regression.  ``beta`` has 8 coefficients (b1..b8)."""

    beta: np.ndarray

    def predict(self, dims: GemmDims) -> float:
        return float(max(_features(dims) @ self.beta, 1e-9))

    @staticmethod
    def fit(samples: Sequence[Tuple[GemmDims, float]]) -> "SingleCoreModel":
        x = np.stack([_features(d) for d, _ in samples])
        y = np.array([t for _, t in samples])
        # Weighted least squares in relative error: scale rows by 1/y so
        # small layers are not drowned out by the large ones.
        w = 1.0 / np.maximum(y, 1e-9)
        beta, *_ = np.linalg.lstsq(x * w[:, None], y * w, rcond=None)
        return SingleCoreModel(beta=beta)

    def mean_abs_pct_error(
        self, samples: Sequence[Tuple[GemmDims, float]]
    ) -> float:
        errs = [
            abs(self.predict(d) - t) / max(t, 1e-12) for d, t in samples
        ]
        return 100.0 * float(np.mean(errs))


@dataclasses.dataclass
class MultiCoreModel:
    """Eqs. 6-8.  ``alpha = (a1, a2, a3)``; ``tile_size`` is ARM-CL's ts."""

    single: SingleCoreModel
    alpha: Tuple[float, float, float] = (0.0, 0.0, 0.0)
    tile_size: int = 16

    def n_iter(self, dims: GemmDims) -> int:
        return max(1, math.ceil(dims.N / self.tile_size))

    def predict(self, dims: GemmDims, cores: int, speed: float = 1.0) -> float:
        """Execution time of one layer's GEMM on ``cores`` homogeneous cores
        of relative speed ``speed`` (equal split, Eq. 8)."""
        return self.predict_from_t1(dims, self.single.predict(dims), cores, speed)

    def predict_from_t1(
        self, dims: GemmDims, t1: float, cores: int, speed: float = 1.0
    ) -> float:
        """Eq. 6-8 scaling from an arbitrary single-stream time ``t1``
        (reference-speed seconds).  This is how *measured* kernel times —
        e.g. the autotuner's per-layer route measurements — replace the
        Eq. 5 regression while keeping the paper's multi-core model."""
        if cores < 1:
            raise ValueError("cores must be >= 1")
        t1 = t1 / speed
        a1, a2, a3 = self.alpha
        n_it = self.n_iter(dims)
        t_iter = (t1 - a1) / n_it + a2 / speed
        # The slowest thread executes ceil(n_iter / H) iterations (Eq. 7).
        iters_slowest = math.ceil(n_it / cores)
        return max(t_iter * iters_slowest + a3, 1e-9)

    @staticmethod
    def fit(
        single: SingleCoreModel,
        samples: Sequence[Tuple[GemmDims, int, float]],
        tile_size: int = 16,
    ) -> "MultiCoreModel":
        """Fit (a1, a2, a3) from (dims, cores, measured_time) samples.

        Rearranging Eq. 7 with equal split gives a linear system in
        (a1, a2, a3):  T_multi = c/H' - a1/n_iter*H'' + a2*... ;  we fit by
        least squares on the residual against the alpha-free prediction.
        """
        model = MultiCoreModel(single=single, alpha=(0.0, 0.0, 0.0), tile_size=tile_size)
        rows, ys = [], []
        for dims, cores, t in samples:
            t1 = single.predict(dims)
            n_it = model.n_iter(dims)
            iters_slowest = math.ceil(n_it / cores)
            base = (t1 / n_it) * iters_slowest
            # T = base - a1*(iters/n_iter) + a2*iters + a3
            rows.append([-iters_slowest / n_it, iters_slowest, 1.0])
            ys.append(t - base)
        a, *_ = np.linalg.lstsq(np.array(rows), np.array(ys), rcond=None)
        return MultiCoreModel(single=single, alpha=(float(a[0]), float(a[1]), float(a[2])), tile_size=tile_size)


@dataclasses.dataclass
class LayerTimePredictor:
    """Produces the paper's time matrix T: layers x stage configurations.

    ``T[l][(core_type, count)]`` = predicted seconds for layer ``l`` on that
    homogeneous stage configuration (paper §VI-A).

    ``measured`` maps autotuner descriptor keys
    (:func:`repro.kernels.autotune.descriptor_key`) to measured
    single-stream route seconds; layers present there use
    ``predict_from_t1`` (measured t1, Eq. 6-8 core scaling) so the time
    matrix reflects the kernels that actually serve, and only unmeasured
    layers fall back to the Eq. 5 regression prior.
    """

    model: MultiCoreModel
    platform: HeteroPlatform
    measured: Optional[Dict[str, float]] = None

    def layer_time(
        self,
        desc: ConvDescriptor,
        stage: StageConfig,
        freq_hz: Optional[float] = None,
    ) -> float:
        """Predicted seconds for one layer on ``stage``, optionally at a
        non-top OPP: the Eq. 5/8 prior (or a measured t1) is scaled by the
        cluster's ``(f_max/f)^kappa`` latency factor (platform.py) — the
        DVFS extension of the paper's frequency-blind model.  ``None``
        means f_max, reproducing the legacy prediction exactly."""
        core_type, count = stage
        scale = self.platform.freq_scale(core_type, freq_hz)
        if self.measured:
            from ..kernels.autotune import descriptor_key

            t1 = self.measured.get(descriptor_key(desc))
            if t1 is not None:
                return scale * self.model.predict_from_t1(
                    desc.gemm_dims(), t1, cores=count,
                    speed=self.platform.speed(core_type),
                )
        return scale * self.model.predict(
            desc.gemm_dims(), cores=count, speed=self.platform.speed(core_type)
        )

    def time_matrix(
        self, layers: Sequence[ConvDescriptor]
    ) -> List[Dict[StageConfig, float]]:
        vocab = self.platform.stage_vocabulary()
        return [
            {stage: self.layer_time(desc, stage) for stage in vocab}
            for desc in layers
        ]

    def freq_time_matrix(
        self, layers: Sequence[ConvDescriptor]
    ) -> "FreqTimeMatrix":
        """The DVFS-extended time matrix: ``T[l][(core_type, count, f)]``
        over every stage configuration x the cluster's OPP table (a
        fixed-clock cluster contributes one ``(ct, n, None)`` entry).
        The planner's frequency-assignment search (core/dse.py) consumes
        the equivalent factored form (2-D matrix x freq_scale) — this
        explicit product form is the validation/reporting view."""
        vocab = self.platform.stage_vocabulary()
        out: FreqTimeMatrix = []
        for desc in layers:
            row: Dict[Tuple[str, int, Optional[float]], float] = {}
            for stage in vocab:
                freqs = self.platform.freq_levels(stage[0]) or (None,)
                for f in freqs:
                    row[(*stage, f)] = self.layer_time(desc, stage, f)
            out.append(row)
        return out

    def time_matrices(
        self, layers_by_model: "Mapping[str, Sequence[ConvDescriptor]]"
    ) -> "Dict[str, List[Dict[StageConfig, float]]]":
        """Time matrices for several co-resident models at once, with one
        shared per-geometry memo: layer times depend only on descriptor
        geometry (the autotuner cache key), and zoo CNNs share many conv
        shapes, so the partition DSE's M-model input costs roughly the
        number of *unique* geometries rather than the total layer count."""
        from ..kernels.autotune import descriptor_key

        vocab = self.platform.stage_vocabulary()
        memo: Dict[str, Dict[StageConfig, float]] = {}
        out: Dict[str, List[Dict[StageConfig, float]]] = {}
        for name, layers in layers_by_model.items():
            rows = []
            for desc in layers:
                key = descriptor_key(desc)
                row = memo.get(key)
                if row is None:
                    row = {stage: self.layer_time(desc, stage) for stage in vocab}
                    memo[key] = row
                rows.append(dict(row))
            out[name] = rows
        return out
