"""Tail-latency model for an open-loop pipeline (ROADMAP item 4).

Pipe-it's Eq. 12 plans for *saturation throughput*: 1/max_i T_{L_i}^{P_i}.
Under an open-loop arrival process (requests arrive whether or not the
board is ready — the serving regime, not the benchmark regime) the
binding constraint becomes the *waiting time* ahead of the bottleneck
stage.  This module layers a queueing model on top of the same stage-time
matrix the DSE already uses:

* Each stage is a deterministic server: the Eq. 12 stage time
  T_{L_i}^{P_i} is a constant service time (CNN inference has no
  data-dependent control flow).  A stage's core count enters through
  that multi-core service time — this is the "M/D/c-style" model: c
  cores shorten D rather than forming c independent servers, because the
  runtime data-parallelizes ONE image across the stage's cores.
* Poisson arrivals at rate ``lambda`` make stage 0 an M/D/1 queue.  For
  a *tandem* line of deterministic servers fed by one Poisson stream,
  Friedman's reduction applies: the end-to-end delay distribution equals
  (sum of all service times + transfers) + the waiting time of a single
  M/D/1 queue at the *slowest* stage, independent of stage order —
  interior stages see arrivals already smoothed by upstream service, so
  only the bottleneck accumulates a queue.
* The M/D/1 waiting-time CDF is exact (Erlang):

      P(W <= t) = (1-rho) * sum_{j=0}^{floor(t/D)}
                  [lambda (jD - t)]^j / j! * e^{-lambda (jD - t)}

  inverted by bisection for p50/p95/p99.  The alternating series is
  evaluated directly while ``lambda*t`` is small enough for double
  precision and switched to the exact asymptotic exponential tail
  ``P(W > t) ~ A e^{-theta t}`` beyond that (DESIGN.md §8).

``predict_latency(plan, T, platform, rate)`` is the public surface the
SLO-aware DSE (``pipe_it_search(slo_p99_ms=..., arrival_rate=...)``) and
the queue-aware governor rank candidates with; ``core.simulator`` is the
ground truth it is validated against (tests/test_queueing.py pins the
tolerance band below ~0.85 utilization).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from .pipeline import PipelinePlan, TimeMatrix
from .platform import HeteroPlatform

# Largest lambda*t the alternating Erlang series is summed directly for.
# Terms can reach ~e^{lambda*t}, so the cancellation error is about
# eps * n_terms * e^{lambda*t}: ~1e-10 absolute at 12, but already
# ~1e-3 at 30 — worse than the tail probabilities being resolved
# (tests/test_queueing.py pins CDF monotonicity/continuity across the
# hand-off).  Beyond the switch the continuity-matched asymptotic
# exponential tail is strictly more accurate.
_DIRECT_MAX = 12.0


def empirical_percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile — THE canonical implementation.

    The value at (1-based) rank ``ceil(q/100 * N)`` of the sorted
    samples (clamped to [1, N]); 0.0 on empty input.  Lives in core so
    the simulator can report latency percentiles without importing the
    serving package; ``serving.metrics.percentile`` delegates here so
    serving metrics and queueing predictions can never disagree on the
    same samples.
    """
    if not values:
        return 0.0
    xs = sorted(values)
    rank = max(1, math.ceil(q / 100.0 * len(xs)))
    return xs[min(rank, len(xs)) - 1]


# --------------------------------------------------------------- M/D/1 core
def md1_mean_wait(rate: float, service_s: float) -> float:
    """Pollaczek-Khinchine mean wait for M/D/1: rho*D / (2(1-rho))."""
    rho = rate * service_s
    if rho >= 1.0:
        return math.inf
    if rho <= 0.0:
        return 0.0
    return rho * service_s / (2.0 * (1.0 - rho))


def _md1_decay_rate(rate: float, service_s: float) -> float:
    """The tail exponent theta: smallest positive root of
    lambda + theta = lambda * e^{theta D} (P(W>t) ~ A e^{-theta t})."""
    rho = rate * service_s
    # Newton from the quadratic approximation u0 = 2(1-rho)/rho,
    # u = theta*D; g(u) = rho*(e^u - 1) - u is convex with g(0)=0.
    u = 2.0 * (1.0 - rho) / rho
    for _ in range(50):
        g = rho * (math.exp(u) - 1.0) - u
        gp = rho * math.exp(u) - 1.0
        if gp <= 0.0:
            break
        step = g / gp
        u -= step
        if abs(step) < 1e-14 * max(u, 1.0):
            break
    return max(u, 1e-300) / service_s


def _md1_cdf_direct(t: float, rate: float, service_s: float) -> float:
    """Exact Erlang series for P(W <= t); valid while lambda*t is small."""
    rho = rate * service_s
    k = int(math.floor(t / service_s))
    total = 0.0
    for j in range(k + 1):
        x = rate * (j * service_s - t)  # <= 0
        total += (x ** j) / math.factorial(j) * math.exp(-x)
    return min(max((1.0 - rho) * total, 0.0), 1.0)


def md1_wait_cdf(t: float, rate: float, service_s: float) -> float:
    """P(W <= t) for the M/D/1 waiting time (exact below the numeric
    switch point, asymptotic exponential tail beyond it)."""
    if service_s <= 0.0 or rate <= 0.0:
        return 1.0 if t >= 0.0 else 0.0
    rho = rate * service_s
    if rho >= 1.0:
        return 0.0  # unstable: no steady-state wait distribution
    if t < 0.0:
        return 0.0
    if rate * t <= _DIRECT_MAX:
        return _md1_cdf_direct(t, rate, service_s)
    # Continuity-matched tail: A = P(W > t*) e^{theta t*} at the largest
    # directly-summable point t*.
    t_star = _DIRECT_MAX / rate
    theta = _md1_decay_rate(rate, service_s)
    tail_star = max(1.0 - _md1_cdf_direct(t_star, rate, service_s), 0.0)
    return min(1.0, 1.0 - tail_star * math.exp(-theta * (t - t_star)))


def md1_wait_quantile(q: float, rate: float, service_s: float) -> float:
    """The q-quantile (q in [0,1)) of the M/D/1 waiting time, by
    bisection on the exact CDF.  inf when the queue is unstable."""
    if not 0.0 <= q < 1.0:
        raise ValueError(f"quantile {q} outside [0, 1)")
    if service_s <= 0.0 or rate <= 0.0:
        return 0.0
    rho = rate * service_s
    if rho >= 1.0:
        return math.inf
    if q <= 1.0 - rho + 1e-15:
        return 0.0  # P(W = 0) = 1 - rho
    lo, hi = 0.0, max(4.0 * md1_mean_wait(rate, service_s), service_s)
    for _ in range(200):
        if md1_wait_cdf(hi, rate, service_s) >= q:
            break
        hi *= 2.0
    for _ in range(100):
        mid = 0.5 * (lo + hi)
        if md1_wait_cdf(mid, rate, service_s) >= q:
            hi = mid
        else:
            lo = mid
    return hi


# ----------------------------------------------------------- plan-level API
@dataclasses.dataclass(frozen=True)
class LatencyPrediction:
    """End-to-end latency of one plan under one Poisson arrival rate."""

    arrival_rate: float  # images/s offered
    stable: bool  # bottleneck utilization < 1
    utilization: float  # rho at the bottleneck stage
    stage_utilization: Tuple[float, ...]
    base_latency_s: float  # sum of services + transfers (zero-queue latency)
    bottleneck_s: float  # D of the reduced M/D/1 queue
    mean_wait_s: float
    p50_s: float
    p95_s: float
    p99_s: float

    def quantile(self, q: float) -> float:
        """End-to-end latency at an arbitrary quantile q in [0, 1)."""
        if not self.stable:
            return math.inf
        w = md1_wait_quantile(q, self.arrival_rate, self.bottleneck_s)
        return self.base_latency_s + w


def _plan_services(
    plan: PipelinePlan,
    T: TimeMatrix,
    platform: HeteroPlatform,
    stage_freqs: Optional[Sequence[Optional[float]]],
    boundary_bytes: Optional[Sequence[int]],
) -> Tuple[List[float], List[float]]:
    """Per-stage service times (freq-scaled) and boundary transfers,
    mirroring ``core.simulator.simulate`` exactly."""
    p = plan.pipeline.p
    service = plan.stage_times(T)
    if stage_freqs is not None:
        if len(stage_freqs) != p:
            raise ValueError(f"{len(stage_freqs)} stage_freqs for {p} stages")
        service = [
            t * platform.freq_scale(stage[0], f)
            for t, stage, f in zip(service, plan.pipeline.stages, stage_freqs)
        ]
    if boundary_bytes is None:
        boundary_bytes = [0] * max(p - 1, 0)
    transfer = []
    for i in range(p - 1):
        (ta, _), (tb, _) = plan.pipeline.stages[i], plan.pipeline.stages[i + 1]
        nbytes = boundary_bytes[i]
        transfer.append(platform.transfer_time(nbytes) if ta != tb and nbytes else 0.0)
    return service, transfer


def predict_latency(
    plan: PipelinePlan,
    T: TimeMatrix,
    platform: HeteroPlatform,
    rate: float,
    *,
    stage_freqs: Optional[Sequence[Optional[float]]] = None,
    boundary_bytes: Optional[Sequence[int]] = None,
) -> LatencyPrediction:
    """Predict end-to-end p50/p95/p99 for ``plan`` under Poisson arrivals
    at ``rate`` images/s — the analytic counterpart of
    ``simulate(..., arrival_s=poisson_trace(rate, ...).times)``.

    An unstable plan (rate >= Eq.12 throughput) reports infinite
    percentiles and ``stable=False``; SLO-aware search ranks it last.
    """
    if rate < 0.0:
        raise ValueError(f"arrival rate {rate} < 0")
    service, transfer = _plan_services(plan, T, platform, stage_freqs, boundary_bytes)
    base = sum(service) + sum(transfer)
    bottleneck = max(service) if service else 0.0
    utils = tuple(rate * s for s in service)
    rho = rate * bottleneck
    stable = rho < 1.0
    if stable:
        p50, p95, p99 = (
            base + md1_wait_quantile(q, rate, bottleneck)
            for q in (0.50, 0.95, 0.99)
        )
        mean_wait = md1_mean_wait(rate, bottleneck)
    else:
        p50 = p95 = p99 = math.inf
        mean_wait = math.inf
    return LatencyPrediction(
        arrival_rate=rate,
        stable=stable,
        utilization=rho,
        stage_utilization=utils,
        base_latency_s=base,
        bottleneck_s=bottleneck,
        mean_wait_s=mean_wait,
        p50_s=p50,
        p95_s=p95,
        p99_s=p99,
    )


def mixture_latency_quantile(
    predictions: Sequence[LatencyPrediction],
    weights: Sequence[float],
    q: float,
) -> float:
    """Quantile of a mixture of per-phase latency distributions.

    Used for phase-modulated arrivals (MMPP burst/calm) under the
    quasi-stationary approximation: each phase contributes its stationary
    latency distribution weighted by the fraction of *arrivals* it
    carries (w_i ~ rate_i * dwell_i).  Valid when phase dwell times are
    long against the queue's relaxation time (DESIGN.md §8).
    """
    if len(predictions) != len(weights) or not predictions:
        raise ValueError("predictions and weights must be equal-length, non-empty")
    wsum = float(sum(weights))
    if wsum <= 0.0:
        raise ValueError("weights must have positive sum")
    ws = [w / wsum for w in weights]
    stable_mass = sum(w for w, p in zip(ws, predictions) if p.stable)
    if q >= stable_mass - 1e-15:
        return math.inf  # the unstable phase owns this quantile

    def cdf(t: float) -> float:
        total = 0.0
        for w, p in zip(ws, predictions):
            if not p.stable or t < p.base_latency_s:
                continue
            total += w * md1_wait_cdf(
                t - p.base_latency_s, p.arrival_rate, p.bottleneck_s
            )
        return total

    lo = 0.0
    hi = max(
        p.quantile(min(q, 0.999)) for p in predictions if p.stable
    ) + max(p.base_latency_s for p in predictions)
    for _ in range(200):
        if cdf(hi) >= q:
            break
        hi *= 2.0
    for _ in range(100):
        mid = 0.5 * (lo + hi)
        if cdf(mid) >= q:
            hi = mid
        else:
            lo = mid
    return hi


def predict_mmpp_latency(
    plan: PipelinePlan,
    T: TimeMatrix,
    platform: HeteroPlatform,
    *,
    calm_rate: float,
    burst_rate: float,
    calm_s: float,
    burst_s: float,
    stage_freqs: Optional[Sequence[Optional[float]]] = None,
    boundary_bytes: Optional[Sequence[int]] = None,
) -> Tuple[float, float, float]:
    """Quasi-stationary (p50, p95, p99) under a 2-state MMPP: per-phase
    stationary predictions mixed by arrival mass.  Conservative planning
    should additionally check the burst phase alone via
    ``predict_latency(plan, ..., burst_rate)``."""
    preds = [
        predict_latency(
            plan, T, platform, r,
            stage_freqs=stage_freqs, boundary_bytes=boundary_bytes,
        )
        for r in (calm_rate, burst_rate)
    ]
    weights = [calm_rate * calm_s, burst_rate * burst_s]
    return tuple(
        mixture_latency_quantile(preds, weights, q) for q in (0.50, 0.95, 0.99)
    )
