"""Pipeline configuration types, throughput (Eq. 12) and design-space size
(Eqs. 1-2).

A pipeline ``P = {P_1..P_p}`` is an ordered tuple of stage configurations
(homogeneous ``(core_type, count)`` tuples, fastest stages first — paper
§VI-B).  The layer allocation ``L = {L_1..L_p}`` partitions the ordered
layer list into contiguous (possibly empty) ranges.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from .platform import HeteroPlatform, StageConfig

TimeMatrix = Sequence[Dict[StageConfig, float]]  # T[layer][stage_config]
Allocation = Tuple[Tuple[int, ...], ...]  # L: per stage, tuple of layer ids


def stage_time(T: TimeMatrix, layers: Sequence[int], stage: StageConfig) -> float:
    """Eq. 10: T_{L_i}^{P_i} = sum of layer times on that stage config."""
    return sum(T[l][stage] for l in layers)


@dataclasses.dataclass(frozen=True)
class Pipeline:
    stages: Tuple[StageConfig, ...]

    def __post_init__(self) -> None:
        if not self.stages:
            raise ValueError("pipeline needs >= 1 stage")

    @property
    def p(self) -> int:
        return len(self.stages)

    def validate_against(self, platform: HeteroPlatform) -> None:
        used: Dict[str, int] = {}
        for core_type, count in self.stages:
            if count < 1:
                raise ValueError(f"stage with {count} cores")
            used[core_type] = used.get(core_type, 0) + count
        avail = platform.counts()
        for ct, n in used.items():
            if n > avail.get(ct, 0):
                raise ValueError(f"pipeline uses {n} {ct!r} cores, only {avail.get(ct, 0)} exist")

    def notation(self) -> str:
        """Paper notation, e.g. 'B4-s2-s2'."""
        return "-".join(f"{t}{n}" for t, n in self.stages)


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """A pipeline plus its layer allocation."""

    pipeline: Pipeline
    allocation: Allocation  # same length as pipeline.stages

    def __post_init__(self) -> None:
        if len(self.allocation) != self.pipeline.p:
            raise ValueError("allocation length != number of stages")

    def stage_times(self, T: TimeMatrix) -> List[float]:
        return [
            stage_time(T, layers, stage)
            for layers, stage in zip(self.allocation, self.pipeline.stages)
        ]

    def bottleneck(self, T: TimeMatrix) -> float:
        return max(self.stage_times(T))

    def throughput(self, T: TimeMatrix) -> float:
        """Eq. 12: 1 / max_i T_{L_i}^{P_i}."""
        return 1.0 / max(self.bottleneck(T), 1e-12)

    def notation(self) -> str:
        ranges = []
        for layers in self.allocation:
            if layers:
                ranges.append(f"[{layers[0] + 1},{layers[-1] + 1}]")
            else:
                ranges.append("[]")
        return f"{self.pipeline.notation()}  {' - '.join(ranges)}"


def contiguous_allocation(split_points: Sequence[int], n_layers: int, p: int) -> Allocation:
    """Build L from ordered split points (len p-1, values in [0, n_layers])."""
    bounds = [0, *split_points, n_layers]
    return tuple(tuple(range(a, b)) for a, b in zip(bounds[:-1], bounds[1:]))


def num_pipelines(h_big: int, h_small: int, p: int) -> int:
    """Eq. 1: number of distinct p-stage pipelines on (H_B + H_s) cores."""
    total = 0
    for p_b in range(max(1, p - h_small), min(h_big, p - 1) + 1):
        p_s = p - p_b
        total += math.comb(h_big - 1, p_b - 1) * math.comb(h_small - 1, p_s - 1)
    return total


def design_space_size(w: int, h_big: int, h_small: int) -> int:
    """Eq. 2: total design points for a CNN with W major layers."""
    h = h_big + h_small
    return sum(
        math.comb(w - 1, p - 1) * num_pipelines(h_big, h_small, p)
        for p in range(2, h + 1)
    )


def _compositions(total: int, parts: int) -> List[Tuple[int, ...]]:
    if parts == 0:
        return [()] if total == 0 else []
    if parts == 1:
        return [(total,)] if total >= 1 else []
    res = []
    for first in range(1, total - parts + 2):
        for rest in _compositions(total - first, parts - 1):
            res.append((first, *rest))
    return res


def enumerate_pipelines(
    platform: HeteroPlatform, p: int, allow_partial: bool = False
) -> List[Pipeline]:
    """All pipelines with exactly p stages, faster cluster types first
    (paper §VI-B orders stages by decreasing compute capability,
    eliminating heterogeneous stages and Small-before-Big orders).

    Generalized to any number of cluster types (the TPU adaptation uses a
    single homogeneous chip type whose stage 'capability' is group size);
    not every cluster needs to contribute stages — unused clusters idle,
    except that every core of a cluster that IS used must be assigned
    (the paper never leaves partial clusters idle).

    ``allow_partial=True`` lifts that last rule: a used cluster's stages
    may sum to ANY total <= its count.  This is the closure of what the
    DSE heuristics can *emit* (merge/sweep drop stages that received no
    layers, stranding that stage's cores), which is the plan space the
    multi-model partition oracle must rank over (core/dse.py)."""
    cts = list(platform.core_types)
    out: List[Pipeline] = []

    def rec(i: int, remaining: int, acc: List[StageConfig]):
        if i == len(cts):
            if remaining == 0 and acc:
                out.append(Pipeline(stages=tuple(acc)))
            return
        ct = cts[i]
        # this cluster contributes k stages (0..min(count, remaining))
        for k in range(0, min(ct.count, remaining) + 1):
            if k == 0:
                rec(i + 1, remaining, acc)
                continue
            totals = range(k, ct.count + 1) if allow_partial else (ct.count,)
            for total in totals:
                for comp in _compositions(total, k):
                    rec(i + 1, remaining - k, acc + [(ct.name, n) for n in comp])

    rec(0, p, [])
    return [pl for pl in out if pl.p == p]
