"""Unified plan IR and the single evaluator every search ranks through.

PRs 4-6 grew the DSE one dimension at a time — pipeline x allocation
(:class:`~.pipeline.PipelinePlan`), per-stage DVFS
(:class:`~.dse.PowerAwarePlan`), tail-latency SLOs
(:class:`~.dse.SloPlan`) and multi-model cluster shares
(:class:`~.dse.ModelPlan`/:class:`~.dse.PartitionPlan`) — each with its
own ad-hoc score/feasibility convention.  This module collapses the
point in the design space to ONE frozen, JSON-serialisable value
(:class:`Plan`) and the ranking to ONE code path (:func:`evaluate`):

* **Objectives** are pluggable functions ``PlanMetrics -> tuple`` whose
  return value is compared lexicographically (first element is the
  reported scalar score, later elements break ties).  The built-ins in
  :data:`OBJECTIVES` reproduce the legacy scores bit-for-bit
  (tests/test_plan_ir.py pins this on the ground-truth matrices).
* **Constraints** are pluggable predicates that either pass or report a
  ``(severity, tail)`` violation.  An :class:`Evaluation`'s ``rank`` is
  ``(2, *objective)`` when every constraint passes, else
  ``(severity, *tail)`` of the most severe violation — so a feasible
  plan beats any infeasible one, and infeasible plans order by *why*
  they fail (a blown power cap ranks by proximity to the envelope; a
  missed throughput floor ranks by best effort).  This is exactly the
  feasibility-first lexicographic idiom the legacy ``_power_rank_key`` /
  ``_slo_rank_key`` / partition share keys implemented three separate
  times (DESIGN.md §9 has the migration map).
* **Backends**: ``backend="model"`` scores analytically (Eq. 10/12 stage
  times, the §7 power model, the §8 M/D/1 tail); ``backend="simulate"``
  reuses :func:`core.simulator.simulate` as the ground-truth evaluator —
  same metrics struct, same objectives, same constraints, so a model
  score and its simulator cross-check can never diverge structurally.

The aggregate multi-model scoring (fairness modes + SLO shortfalls)
lives here too (:func:`partition_parts` / :func:`partition_rank_key`),
so ``partition_search``'s share ranking is the same machinery.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import (
    Any,
    Callable,
    Dict,
    List,
    Mapping,
    Optional,
    Sequence,
    Tuple,
    Union,
)

from .pipeline import Allocation, Pipeline, PipelinePlan, TimeMatrix
from .platform import HeteroPlatform, StageConfig
from .queueing import LatencyPrediction, predict_latency
from .simulator import simulate

#: Per-stage OPP choice; None marks a fixed-clock cluster's single level.
FreqAssignment = Tuple[Optional[float], ...]

#: ((core_type, count), ...) — one model's disjoint slice of the cluster.
Share = Tuple[Tuple[str, int], ...]

#: Relative-shortfall penalty that ranks every SLO-feasible assignment above
#: every infeasible one while keeping infeasible ones ordered by how close
#: they come (best-effort under overload).
SLO_PENALTY = 1e9


# --------------------------------------------------------------------- the IR
@dataclasses.dataclass(frozen=True)
class Plan:
    """One point of the full design space, in every dimension the DSE has.

    ``stages``/``allocation`` are the paper's pipeline x layer-split
    (always present); the remaining fields are the beyond-paper axes and
    default to "not planned": ``stage_freqs`` (per-stage OPP, None inside
    the tuple = fixed-clock cluster), ``model``/``share`` (which
    co-resident model this plan serves and on which cluster slice).
    Frozen + hashable + JSON round-trippable so plans can be cache keys,
    golden fixtures, and wire payloads.
    """

    stages: Tuple[StageConfig, ...]
    allocation: Allocation
    stage_freqs: Optional[FreqAssignment] = None
    model: Optional[str] = None
    share: Optional[Share] = None

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "stages", tuple((str(ct), int(n)) for ct, n in self.stages)
        )
        object.__setattr__(
            self, "allocation", tuple(tuple(int(x) for x in a) for a in self.allocation)
        )
        if len(self.allocation) != len(self.stages):
            raise ValueError(
                f"{len(self.allocation)} allocation groups for "
                f"{len(self.stages)} stages"
            )
        if self.stage_freqs is not None:
            object.__setattr__(self, "stage_freqs", tuple(self.stage_freqs))
            if len(self.stage_freqs) != len(self.stages):
                raise ValueError(
                    f"{len(self.stage_freqs)} stage_freqs for "
                    f"{len(self.stages)} stages"
                )
        if self.share is not None:
            object.__setattr__(
                self, "share", tuple((str(ct), int(n)) for ct, n in self.share)
            )

    # ------------------------------------------------------------- views
    @property
    def p(self) -> int:
        return len(self.stages)

    @property
    def pipeline(self) -> Pipeline:
        return Pipeline(stages=self.stages)

    def as_pipeline_plan(self) -> PipelinePlan:
        """The legacy throughput-only view (drops the extra dimensions)."""
        return PipelinePlan(self.pipeline, self.allocation)

    def with_freqs(self, stage_freqs: Optional[Sequence[Optional[float]]]) -> "Plan":
        return dataclasses.replace(
            self,
            stage_freqs=None if stage_freqs is None else tuple(stage_freqs),
        )

    def notation(self) -> str:
        """Human notation across every planned dimension, e.g.
        ``alexnet@B4-s2-s2 [1,5][6,7][8,8] @ fix/1.84GHz/1.84GHz``."""
        text = self.as_pipeline_plan().notation()
        if self.stage_freqs is not None:
            freqs = "/".join(
                "fix" if f is None else f"{f / 1e9:.2f}GHz"
                for f in self.stage_freqs
            )
            text = f"{text}  @ {freqs}"
        if self.model is not None:
            text = f"{self.model}@{text}"
        return text

    # ------------------------------------------------------- JSON round-trip
    def to_dict(self) -> Dict[str, Any]:
        return {
            "stages": [list(s) for s in self.stages],
            "allocation": [list(a) for a in self.allocation],
            "stage_freqs": (
                None if self.stage_freqs is None else list(self.stage_freqs)
            ),
            "model": self.model,
            "share": None if self.share is None else [list(s) for s in self.share],
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Plan":
        return cls(
            stages=tuple((ct, n) for ct, n in d["stages"]),
            allocation=tuple(tuple(a) for a in d["allocation"]),
            stage_freqs=(
                None
                if d.get("stage_freqs") is None
                else tuple(d["stage_freqs"])
            ),
            model=d.get("model"),
            share=(
                None
                if d.get("share") is None
                else tuple((ct, n) for ct, n in d["share"])
            ),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_json(cls, text: str) -> "Plan":
        return cls.from_dict(json.loads(text))

    # ------------------------------------------------------ legacy adapters
    @classmethod
    def from_legacy(cls, obj: Any) -> "Plan":
        """Convert any of the four legacy plan types (duck-typed, so this
        module never imports ``core.dse``):

        * ``ModelPlan``     -> model + share + inner plan (+ DVFS if any)
        * ``PowerAwarePlan``-> plan + stage_freqs
        * ``SloPlan``       -> plan (the SLO lives in the constraints)
        * ``PipelinePlan``  -> stages + allocation
        """
        if hasattr(obj, "name") and hasattr(obj, "share") and hasattr(obj, "plan"):
            inner = obj.plan
            power = getattr(obj, "power", None)
            return cls(
                stages=inner.pipeline.stages,
                allocation=inner.allocation,
                stage_freqs=None if power is None else tuple(power.stage_freqs),
                model=obj.name,
                share=tuple(
                    (ct.name, ct.count) for ct in obj.share.core_types
                ),
            )
        if hasattr(obj, "plan") and hasattr(obj, "stage_freqs"):
            return cls(
                stages=obj.plan.pipeline.stages,
                allocation=obj.plan.allocation,
                stage_freqs=tuple(obj.stage_freqs),
            )
        if hasattr(obj, "plan") and hasattr(obj, "prediction"):
            return cls(
                stages=obj.plan.pipeline.stages,
                allocation=obj.plan.allocation,
            )
        if hasattr(obj, "pipeline") and hasattr(obj, "allocation"):
            return cls(stages=obj.pipeline.stages, allocation=obj.allocation)
        raise TypeError(f"cannot build a Plan from {type(obj).__name__}")


# ------------------------------------------------------------------- metrics
@dataclasses.dataclass(frozen=True)
class PlanMetrics:
    """Everything an objective or constraint may score a plan on.

    Filled by either backend of :func:`evaluate`; ``prediction`` is the
    full analytic M/D/1 record (model backend with an ``arrival_rate``),
    while ``p99_s`` alone is also set by the simulator backend (measured
    tail, no analytic structure behind it).
    """

    stage_times_s: Tuple[float, ...]  # per-stage service at the plan's OPPs
    cycle_s: float  # max stage time (clamped) — Eq. 12 denominator
    throughput: float  # 1 / cycle_s (img/s)
    energy_per_image_j: float  # sum_i P_i * t_i (0 when no DVFS dimension)
    avg_power_w: float  # energy / cycle
    p99_s: Optional[float] = None  # end-to-end p99 (None: latency-blind)
    prediction: Optional[LatencyPrediction] = None
    backend: str = "model"
    # The plan's stage shapes ((core_type, n_cores) per stage) — what
    # placement-sensitive constraints (:class:`Availability`) check.
    # None only for hand-built metrics that predate the field.
    stages: Optional[Tuple[StageConfig, ...]] = None
    # The plan's reserved cluster slice (Plan.share), when the plan was
    # carved by a partition/fleet search — what :class:`Placement` checks
    # in preference to the (possibly smaller) stage demand.
    share: Optional[Share] = None

    @property
    def stable(self) -> bool:
        return True if self.prediction is None else self.prediction.stable

    @property
    def utilization(self) -> float:
        return 0.0 if self.prediction is None else self.prediction.utilization


# ---------------------------------------------------------------- objectives
#: An objective maps metrics to a lexicographic score tuple; element 0 is
#: the reported scalar score, the rest break ties.  Higher is better.
Objective = Callable[[PlanMetrics], Tuple[float, ...]]


def _obj_throughput(m: PlanMetrics) -> Tuple[float, ...]:
    """Max img/s; ties to the cooler plan."""
    return (m.throughput, -m.avg_power_w)


def _obj_throughput_per_watt(m: PlanMetrics) -> Tuple[float, ...]:
    """Max img/s per modeled watt.  Zero MODELED watts (fixed-clock
    clusters) reads as 'free' throughput: the epsilon floor makes such
    plans dominate powered ones (consistent with the model's claim that
    they cost nothing) while ranking among themselves by img/s — so on a
    fully fixed-clock platform the ordering degrades to plain throughput."""
    return (m.throughput / max(m.avg_power_w, 1e-12), -m.avg_power_w)


def _obj_min_energy(m: PlanMetrics) -> Tuple[float, ...]:
    """Min J/image.  Same zero-watts convention: zero modeled joules
    outranks any positive energy; among free plans, more img/s first (the
    tiny positive scale keeps every zero-energy score above every
    -energy one)."""
    e = m.energy_per_image_j
    return ((-e if e > 0.0 else m.throughput * 1e-15), -m.avg_power_w)


def _obj_slo_throughput(m: PlanMetrics) -> Tuple[float, ...]:
    """Max img/s, ties to the lower predicted tail — the feasible-side
    ordering of the SLO-aware search (requires ``arrival_rate``)."""
    p99 = m.p99_s if m.p99_s is not None else 0.0
    return (m.throughput, -p99)


OBJECTIVES: Dict[str, Objective] = {
    "throughput": _obj_throughput,
    "throughput_per_watt": _obj_throughput_per_watt,
    "min_energy": _obj_min_energy,
    "slo_throughput": _obj_slo_throughput,
}

#: Objective names whose score needs a latency prediction.
_NEEDS_RATE = frozenset({"slo_throughput"})


# --------------------------------------------------------------- constraints
#: A violation is ``(severity, tail)``: lower severity = worse failure
#: class; the tail orders plans *within* that failure class (higher is
#: better, i.e. closer to feasible / better best-effort).  Severities are
#: chosen so ``(2, *objective)`` (feasible) always wins.
Violation = Tuple[int, Tuple[float, ...]]


@dataclasses.dataclass(frozen=True)
class PowerCap:
    """Average modeled active power must stay under ``cap_w``.

    A violation is a *safety* failure (severity 0): violators rank by
    least power first — closest to the envelope — not by score."""

    cap_w: float
    tolerance: float = 1e-9
    name: str = dataclasses.field(default="power_cap", repr=False)

    def violation(
        self, m: PlanMetrics, score: Tuple[float, ...]
    ) -> Optional[Violation]:
        if m.avg_power_w <= self.cap_w * (1 + self.tolerance):
            return None
        return (0, (-m.avg_power_w, score[0]))


@dataclasses.dataclass(frozen=True)
class MinThroughput:
    """Eq. 12 throughput must reach ``floor`` img/s (the iso-throughput /
    SLO-rate deployment).  Missing the floor with the cap intact means
    demand outstrips capacity — best effort is to run as FAST as the
    envelope allows (severity 1, throughput-first tail), not to idle at
    minimum clocks."""

    floor: float
    tolerance: float = 1e-9
    name: str = dataclasses.field(default="min_throughput", repr=False)

    def violation(
        self, m: PlanMetrics, score: Tuple[float, ...]
    ) -> Optional[Violation]:
        if m.throughput >= self.floor * (1 - self.tolerance):
            return None
        return (1, (m.throughput, -m.avg_power_w))


@dataclasses.dataclass(frozen=True)
class SloP99:
    """Capacity-style p99 budget (the power-aware search's convention):
    predicted end-to-end p99 must be within ``slo_p99_s``.  A violation
    ranks like a missed throughput floor — run as fast as allowed
    (severity 1) — because on the DVFS axis a blown tail means the clocks
    are too LOW, and more speed is the remedy."""

    slo_p99_s: float
    tolerance: float = 1e-9
    name: str = dataclasses.field(default="slo_p99", repr=False)

    def violation(
        self, m: PlanMetrics, score: Tuple[float, ...]
    ) -> Optional[Violation]:
        if m.p99_s is None:
            raise ValueError(
                "SloP99 needs a latency estimate — pass arrival_rate to "
                "evaluate() (model backend) or arrival_s (simulate backend)"
            )
        if m.p99_s <= self.slo_p99_s * (1 + self.tolerance):
            return None
        return (1, (m.throughput, -m.avg_power_w))


@dataclasses.dataclass(frozen=True)
class TailSlo:
    """Tail-first p99 budget (the latency-aware search's convention):
    feasible only when the queue is *stable* and p99 fits within
    ``headroom * slo_p99_s`` (the margin absorbs M/D/1-vs-simulator model
    error).  Stable-but-over plans rank closest-to-budget first
    (severity 1); unstable plans rank last, least-overloaded first
    (severity 0)."""

    slo_p99_s: float
    headroom: float = 1.0
    name: str = dataclasses.field(default="tail_slo", repr=False)

    def violation(
        self, m: PlanMetrics, score: Tuple[float, ...]
    ) -> Optional[Violation]:
        if m.p99_s is None:
            raise ValueError(
                "TailSlo needs a latency estimate — pass arrival_rate to "
                "evaluate() (model backend) or arrival_s (simulate backend)"
            )
        if m.stable and m.p99_s <= self.headroom * self.slo_p99_s:
            return None
        if m.stable:
            return (1, (-m.p99_s, m.throughput))
        return (0, (-m.utilization, m.throughput))


@dataclasses.dataclass(frozen=True)
class Availability:
    """The plan must fit on the cores that are still alive.

    The degraded-mode constraint (serving/faults.py): after a permanent
    core/cluster loss, ``alive`` holds the surviving per-core-type
    counts, and any plan whose stages demand more cores of a type than
    survive cannot execute at all — a *safety* failure (severity 0, like
    :class:`PowerCap`).  Violators rank by fewest dead cores demanded
    (closest to schedulable), then by score.  Build from the surviving
    sub-platform with :meth:`from_platform` (the same
    ``HeteroPlatform.subset`` the degraded re-plan searches over).
    """

    alive: Tuple[Tuple[str, int], ...]
    name: str = dataclasses.field(default="availability", repr=False)

    @classmethod
    def from_platform(cls, platform: HeteroPlatform) -> "Availability":
        return cls(
            alive=tuple((ct.name, ct.count) for ct in platform.core_types)
        )

    def violation(
        self, m: PlanMetrics, score: Tuple[float, ...]
    ) -> Optional[Violation]:
        if m.stages is None:
            raise ValueError(
                "Availability needs PlanMetrics.stages — score the plan "
                "through evaluate(), which records stage shapes"
            )
        demand: Dict[str, int] = {}
        for core_type, n in m.stages:
            demand[core_type] = demand.get(core_type, 0) + n
        alive = dict(self.alive)
        missing = sum(
            max(0, n - alive.get(core_type, 0))
            for core_type, n in demand.items()
        )
        if missing == 0:
            return None
        return (0, (-float(missing), score[0]))


@dataclasses.dataclass(frozen=True)
class Placement:
    """The plan must fit on one named board of a fleet.

    The fleet axis of :class:`Availability` (core/fleet.py): ``alive``
    holds the board's per-core-type counts, and a replica plan whose
    reserved cluster share (``PlanMetrics.share``, falling back to the
    stage demand for share-less plans) exceeds them cannot be placed
    there — a safety failure (severity 0).  Violators rank by fewest
    missing cores (closest to placeable), then by score.  Build from a
    board's platform with :meth:`for_board`.
    """

    board: str
    alive: Tuple[Tuple[str, int], ...]
    name: str = dataclasses.field(default="placement", repr=False)

    @classmethod
    def for_board(cls, board: str, platform: HeteroPlatform) -> "Placement":
        return cls(
            board=board,
            alive=tuple((ct.name, ct.count) for ct in platform.core_types),
        )

    def violation(
        self, m: PlanMetrics, score: Tuple[float, ...]
    ) -> Optional[Violation]:
        if m.share is not None:
            demand = {str(ct): int(n) for ct, n in m.share}
        elif m.stages is not None:
            demand = {}
            for core_type, n in m.stages:
                demand[core_type] = demand.get(core_type, 0) + n
        else:
            raise ValueError(
                "Placement needs PlanMetrics.share or .stages — score the "
                "plan through evaluate(), which records both"
            )
        alive = dict(self.alive)
        missing = sum(
            max(0, n - alive.get(core_type, 0))
            for core_type, n in demand.items()
        )
        if missing == 0:
            return None
        return (0, (-float(missing), score[0]))


# ----------------------------------------------------------------- evaluator
@dataclasses.dataclass(frozen=True)
class Evaluation:
    """The unified verdict: metrics + score + feasibility + rank.

    ``rank`` is the ONLY thing searches compare: ``(2, *score)`` when
    feasible, else ``(severity, *tail)`` of the most severe violated
    constraint.  Built so that for any two candidates of the same search,
    ``a.rank > b.rank`` iff the legacy rank key preferred ``a``."""

    plan: Plan
    metrics: PlanMetrics
    objective_name: str
    score: Tuple[float, ...]
    rank: Tuple[float, ...]
    feasible: bool
    binding: Optional[str] = None  # name of the most severe violated constraint


def evaluate(
    plan: Union[Plan, Any],
    T: TimeMatrix,
    platform: HeteroPlatform,
    *,
    objective: Union[str, Objective] = "throughput",
    constraints: Sequence[Any] = (),
    arrival_rate: Optional[float] = None,
    boundary_bytes: Optional[Sequence[int]] = None,
    backend: str = "model",
    n_images: int = 256,
    arrival_s: Optional[Sequence[float]] = None,
) -> Evaluation:
    """Score one plan — the single entry point every search ranks through.

    ``objective`` is a name from :data:`OBJECTIVES` or any callable
    ``PlanMetrics -> tuple``; ``constraints`` is any sequence of objects
    with ``violation(metrics, score) -> Optional[(severity, tail)]``
    (:class:`PowerCap`, :class:`MinThroughput`, :class:`SloP99`,
    :class:`TailSlo`, or user-defined).  ``backend="model"`` is the
    analytic path (what the searches iterate); ``backend="simulate"``
    re-scores the same plan through the discrete-event simulator
    (``arrival_s`` switches it open-loop), so ground-truth cross-checks
    share the objectives/constraints with the search itself.

    Legacy plan objects are accepted and converted via
    :meth:`Plan.from_legacy`.
    """
    if not isinstance(plan, Plan):
        plan = Plan.from_legacy(plan)
    if isinstance(objective, str):
        try:
            obj_fn = OBJECTIVES[objective]
        except KeyError:
            raise ValueError(
                f"unknown objective {objective!r}; one of "
                f"{tuple(OBJECTIVES)} (or pass a callable)"
            ) from None
        obj_name = objective
        if objective in _NEEDS_RATE and arrival_rate is None and arrival_s is None:
            raise ValueError(f"objective {objective!r} requires arrival_rate")
    else:
        obj_fn = objective
        obj_name = getattr(objective, "__name__", "custom")
    pplan = plan.as_pipeline_plan()

    if backend == "model":
        base = pplan.stage_times(T)
        if plan.stage_freqs is None:
            times = list(base)
        else:
            times = [
                t * platform.freq_scale(stage[0], f)
                for t, stage, f in zip(base, plan.stages, plan.stage_freqs)
            ]
        cycle = max(max(times), 1e-12)
        freqs = plan.stage_freqs or (None,) * plan.p
        energy = sum(
            platform.active_power_w(stage[0], stage[1], f) * t
            for stage, f, t in zip(plan.stages, freqs, times)
        )
        prediction = None
        p99 = None
        if arrival_rate is not None:
            prediction = predict_latency(
                pplan,
                T,
                platform,
                arrival_rate,
                stage_freqs=plan.stage_freqs,
                boundary_bytes=boundary_bytes,
            )
            p99 = prediction.p99_s
        metrics = PlanMetrics(
            stage_times_s=tuple(times),
            cycle_s=cycle,
            throughput=1.0 / cycle,
            energy_per_image_j=energy,
            avg_power_w=energy / cycle,
            p99_s=p99,
            prediction=prediction,
            backend="model",
            stages=tuple(plan.stages),
            share=plan.share,
        )
    elif backend == "simulate":
        res = simulate(
            pplan,
            T,
            platform,
            n_images=n_images,
            boundary_bytes=boundary_bytes,
            stage_freqs=plan.stage_freqs,
            arrival_s=arrival_s,
        )
        n_done = max(len(res.finish_times), 1)
        tp = res.steady_throughput
        metrics = PlanMetrics(
            stage_times_s=tuple(res.stage_busy_s),
            cycle_s=(1.0 / tp) if tp > 0.0 else math.inf,
            throughput=tp,
            energy_per_image_j=res.energy_j / n_done,
            avg_power_w=res.avg_power_w,
            p99_s=res.latency_p99_s if arrival_s is not None else None,
            prediction=None,
            backend="simulate",
            stages=tuple(plan.stages),
            share=plan.share,
        )
    else:
        raise ValueError(f"unknown backend {backend!r}; 'model' or 'simulate'")

    score = tuple(obj_fn(metrics))
    worst: Optional[Tuple[int, Tuple[float, ...], str]] = None
    for c in constraints:
        v = c.violation(metrics, score)
        if v is None:
            continue
        sev, tail = v
        nm = getattr(c, "name", type(c).__name__)
        if worst is None or sev < worst[0]:
            worst = (sev, tail, nm)
    if worst is None:
        return Evaluation(
            plan=plan,
            metrics=metrics,
            objective_name=obj_name,
            score=score,
            rank=(2,) + score,
            feasible=True,
        )
    sev, tail, nm = worst
    return Evaluation(
        plan=plan,
        metrics=metrics,
        objective_name=obj_name,
        score=score,
        rank=(sev,) + tuple(tail),
        feasible=False,
        binding=nm,
    )


# ------------------------------------------- multi-model aggregate objectives
#: fairness mode -> aggregator over the weighted per-model throughputs.
#: "sum" is utilitarian (machine-wide goodput), "max-min" egalitarian
#: (the worst model's weighted rate; set w_m = 1/demand_m to equalise
#: heterogeneous demands).
FAIRNESS: Dict[str, Callable[[Sequence[float]], float]] = {
    "sum": sum,
    "max-min": min,
}


def partition_parts(
    throughputs: Sequence[float],
    weights: Optional[Sequence[float]] = None,
    slo_rates: Optional[Sequence[float]] = None,
    fairness: str = "sum",
) -> Tuple[float, float]:
    """(aggregate score, total relative SLO shortfall) for one cluster-share
    assignment — the two components every partition ranking is built from."""
    m = len(throughputs)
    ws = list(weights) if weights is not None else [1.0] * m
    slos = list(slo_rates) if slo_rates is not None else [0.0] * m
    if len(ws) != m or len(slos) != m:
        raise ValueError("weights/slo_rates must match throughputs")
    if fairness not in FAIRNESS:
        raise ValueError(f"unknown fairness {fairness!r}")
    score = FAIRNESS[fairness]([w * tp for w, tp in zip(ws, throughputs)])
    shortfall = sum(
        max(0.0, 1.0 - tp / slo)
        for tp, slo in zip(throughputs, slos)
        if slo > 0.0
    )
    return score, shortfall


def partition_score(
    throughputs: Sequence[float],
    weights: Optional[Sequence[float]] = None,
    slo_rates: Optional[Sequence[float]] = None,
    fairness: str = "sum",
) -> float:
    """The scalar reported form: score minus :data:`SLO_PENALTY` per unit
    of relative shortfall (searches rank via :func:`partition_rank_key`,
    which is immune to throughputs outscaling the finite penalty)."""
    score, shortfall = partition_parts(throughputs, weights, slo_rates, fairness)
    return score - SLO_PENALTY * shortfall


def partition_rank_key(
    score: float, shortfall: float, power_ok: bool = True
) -> Tuple[Any, ...]:
    """Lexicographic share-assignment rank: feasibility (every SLO floor
    met AND every share under its power slice) beats any score, then
    least total miss, then score — the same feasibility-then-score idiom
    :func:`evaluate` uses for single plans."""
    return (shortfall == 0.0 and power_ok, -shortfall, score)
