"""Design-space exploration — the paper's Algorithms 1-3, implemented
faithfully.

* :func:`find_split`  — Algorithm 1: water-flow split of a contiguous layer
  range between two adjacent stages.
* :func:`work_flow`   — Algorithm 2: iterate find_split over all adjacent
  stage pairs until the allocation stabilises.
* :func:`merge_stage` — Algorithm 3: start from one-core-per-stage and merge
  adjacent same-type stages while Eq. 14 predicts an improvement.

The paper's pseudocode for Algorithm 3 "break"s a cluster loop on the first
unhelpful merge; its worked examples (ResNet50 -> B4-s2-s2, MobileNet ->
B2-B2-s3-s1) show that after an unhelpful merge the search *advances to the
next adjacent pair* within the cluster rather than abandoning it — we
implement that semantics (stay on a pair after a successful merge so a
grown stage can keep absorbing, advance past an unhelpful one).

An exhaustive search over (pipeline x contiguous split) is provided for
small instances; tests use it to bound the heuristic's optimality gap.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from .pipeline import (
    Allocation,
    Pipeline,
    PipelinePlan,
    TimeMatrix,
    contiguous_allocation,
    enumerate_pipelines,
    stage_time,
)
from .platform import HeteroPlatform, StageConfig


def find_split(
    layers: Sequence[int],
    T: TimeMatrix,
    stage_a: StageConfig,
    stage_b: StageConfig,
    rule: str = "paper",
) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Algorithm 1: split ``layers`` (ordered) between adjacent stages.

    All work starts on the faster stage ``stage_a``; layers flow one at a
    time from the tail of ``stage_a`` to the head of ``stage_b``.

    rule="paper":  move while the donor stage would remain the bottleneck
      (the paper's exact condition — conservative: it can stop one move
      short of the best split).
    rule="minmax": move while the move strictly reduces
      max(t_left, t_right).  Because t_left is monotonically decreasing
      and t_right monotonically increasing in the number of moved layers,
      the max is unimodal and this greedy rule finds the *optimal*
      contiguous two-way split.  Beyond-paper improvement (DESIGN.md §2).
    """
    left = list(layers)
    right: List[int] = []
    t_left = stage_time(T, left, stage_a)
    t_right = 0.0
    while left:
        lj = left[-1]
        t_left_new = t_left - T[lj][stage_a]
        t_right_new = t_right + T[lj][stage_b]
        if rule == "paper":
            helpful = t_left_new > t_right_new
        elif rule == "minmax":
            helpful = max(t_left_new, t_right_new) < max(t_left, t_right)
        else:
            raise ValueError(f"unknown rule {rule!r}")
        if helpful:  # move of l_j is helpful
            left.pop()
            right.insert(0, lj)
            t_left, t_right = t_left_new, t_right_new
        else:  # further flow of workload will not be helpful
            break
    return tuple(left), tuple(right)


def work_flow(
    pipeline: Pipeline,
    layers: Sequence[int],
    T: TimeMatrix,
    max_rounds: int = 100,
    rule: str = "paper",
) -> Allocation:
    """Algorithm 2: iterative pairwise rebalancing until a fixed point."""
    p = pipeline.p
    alloc: List[Tuple[int, ...]] = [tuple(layers)] + [()] * (p - 1)
    old: Optional[List[Tuple[int, ...]]] = None
    rounds = 0
    while alloc != old and rounds < max_rounds:
        old = list(alloc)
        for i in range(p - 1):
            pool = tuple(alloc[i]) + tuple(alloc[i + 1])
            li, lj = find_split(
                pool, T, pipeline.stages[i], pipeline.stages[i + 1], rule=rule
            )
            alloc[i], alloc[i + 1] = li, lj
        rounds += 1
    return tuple(alloc)


def _plan(pipeline: Pipeline, alloc: Allocation) -> PipelinePlan:
    return PipelinePlan(pipeline=pipeline, allocation=alloc)


def merge_stage(
    layers: Sequence[int],
    platform: HeteroPlatform,
    T: TimeMatrix,
) -> PipelinePlan:
    """Algorithm 3: stage-configuration search by merging.

    Starts from an ``(H_B + H_s)``-stage pipeline of single cores (Big
    stages first), rebalances with work_flow, then greedily merges adjacent
    same-type stages while Eq. 14 holds.
    """
    stages: List[StageConfig] = []
    for ct in platform.core_types:
        stages.extend([(ct.name, 1)] * ct.count)
    pipeline = Pipeline(stages=tuple(stages))
    alloc = work_flow(pipeline, layers, T)

    def eq14_merge_helpful(i: int) -> bool:
        """Eq. 14: merged stage beats the slower of the two originals."""
        (ta, ca), (tb, cb) = pipeline.stages[i], pipeline.stages[i + 1]
        merged: StageConfig = (ta, ca + cb)
        t_merged = stage_time(T, alloc[i] + alloc[i + 1], merged)
        t_i = stage_time(T, alloc[i], pipeline.stages[i])
        t_j = stage_time(T, alloc[i + 1], pipeline.stages[i + 1])
        return t_merged < max(t_i, t_j)

    i = 0
    while i < pipeline.p - 1:
        (ta, _), (tb, _) = pipeline.stages[i], pipeline.stages[i + 1]
        if ta != tb:  # cluster boundary: never mix core types in a stage
            i += 1
            continue
        if eq14_merge_helpful(i):
            new_stages = list(pipeline.stages)
            merged = (ta, new_stages[i][1] + new_stages[i + 1][1])
            new_stages[i : i + 2] = [merged]
            pipeline = Pipeline(stages=tuple(new_stages))
            alloc = work_flow(pipeline, layers, T)
            # stay at i: the grown stage may keep absorbing its neighbour
        else:
            i += 1

    # Drop stages that received no layers (their cores stay idle; the
    # paper's final configurations never contain empty stages).
    kept = [
        (st, al)
        for st, al in zip(pipeline.stages, alloc)
        if al
    ]
    pipeline = Pipeline(stages=tuple(st for st, _ in kept))
    alloc = tuple(al for _, al in kept)
    return _plan(pipeline, alloc)


def pipeline_sweep(
    n_layers: int,
    platform: HeteroPlatform,
    T: TimeMatrix,
) -> PipelinePlan:
    """Beyond-paper mode: the number of distinct *pipelines* is small
    (Eq. 1 gives 64 on the 4+4 platform) — the exponential blow-up is in
    the split points, which ``work_flow`` resolves heuristically.  Running
    work_flow on every pipeline is cheap and never worse than Algorithm 3
    (recorded in DESIGN.md §2 / EXPERIMENTS.md §Perf as an improvement)."""
    layers = list(range(n_layers))
    best: Optional[PipelinePlan] = None
    best_tp = -1.0
    h = platform.total_cores()
    for p in range(1, h + 1):
        pipes = (
            enumerate_pipelines(platform, p)
            if p > 1
            else [Pipeline(stages=((ct.name, ct.count),)) for ct in platform.core_types]
        )
        for pipeline in pipes:
            alloc = work_flow(pipeline, layers, T, rule="minmax")
            kept = [(st, al) for st, al in zip(pipeline.stages, alloc) if al]
            plan = _plan(
                Pipeline(stages=tuple(st for st, _ in kept)),
                tuple(al for _, al in kept),
            )
            tp = plan.throughput(T)
            if tp > best_tp:
                best, best_tp = plan, tp
    assert best is not None
    return best


def pipe_it_search(
    n_layers: int,
    platform: HeteroPlatform,
    T: TimeMatrix,
    mode: str = "merge",
) -> PipelinePlan:
    """The Pipe-it DSE entry point (paper §VI).

    mode="merge"  — the paper's Algorithm 3 (faithful).
    mode="sweep"  — beyond-paper work_flow-over-all-pipelines.
    mode="best"   — run both, return the higher-throughput plan.
    """
    if mode == "merge":
        return merge_stage(list(range(n_layers)), platform, T)
    if mode == "sweep":
        return pipeline_sweep(n_layers, platform, T)
    if mode == "best":
        a = merge_stage(list(range(n_layers)), platform, T)
        b = pipeline_sweep(n_layers, platform, T)
        return a if a.throughput(T) >= b.throughput(T) else b
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# Exhaustive reference search (small instances only; used by tests/benches)
# ---------------------------------------------------------------------------

def exhaustive_two_way_split(
    layers: Sequence[int],
    T: TimeMatrix,
    stage_a: StageConfig,
    stage_b: StageConfig,
) -> Tuple[Tuple[Tuple[int, ...], Tuple[int, ...]], float]:
    """Brute-force optimal contiguous two-way split of ``layers``.

    Tries every prefix/suffix cut (the only splits Algorithm 1 can emit)
    and returns ``((left, right), bottleneck)`` minimising
    ``max(T_left^a, T_right^b)``.  O(n^2); reference oracle for the
    ``find_split`` property tests."""
    ordered = list(layers)
    best: Optional[Tuple[Tuple[int, ...], Tuple[int, ...]]] = None
    best_t = float("inf")
    for k in range(len(ordered) + 1):
        left, right = tuple(ordered[:k]), tuple(ordered[k:])
        t = max(stage_time(T, left, stage_a), stage_time(T, right, stage_b))
        if t < best_t:
            best, best_t = (left, right), t
    assert best is not None
    return best, best_t

def exhaustive_search(
    n_layers: int,
    platform: HeteroPlatform,
    T: TimeMatrix,
    max_stages: Optional[int] = None,
) -> PipelinePlan:
    """Brute-force over every pipeline (Eq. 1) and every contiguous split
    (Eq. 2).  Exponential; only for validating the heuristic."""
    best: Optional[PipelinePlan] = None
    best_tp = -1.0
    h = platform.total_cores()
    top = min(max_stages or h, h, n_layers)
    for p in range(1, top + 1):
        if p == 1:
            # Degenerate single-stage "pipelines": best homogeneous cluster.
            for ct in platform.core_types:
                plan = _plan(
                    Pipeline(stages=((ct.name, ct.count),)),
                    (tuple(range(n_layers)),),
                )
                tp = plan.throughput(T)
                if tp > best_tp:
                    best, best_tp = plan, tp
            continue
        for pipeline in enumerate_pipelines(platform, p):
            for cuts in itertools.combinations(range(1, n_layers), p - 1):
                alloc = contiguous_allocation(cuts, n_layers, p)
                plan = _plan(pipeline, alloc)
                tp = plan.throughput(T)
                if tp > best_tp:
                    best, best_tp = plan, tp
    assert best is not None
    return best
