"""Design-space exploration — the paper's Algorithms 1-3, implemented
faithfully.

* :func:`find_split`  — Algorithm 1: water-flow split of a contiguous layer
  range between two adjacent stages.
* :func:`work_flow`   — Algorithm 2: iterate find_split over all adjacent
  stage pairs until the allocation stabilises.
* :func:`merge_stage` — Algorithm 3: start from one-core-per-stage and merge
  adjacent same-type stages while Eq. 14 predicts an improvement.

The paper's pseudocode for Algorithm 3 "break"s a cluster loop on the first
unhelpful merge; its worked examples (ResNet50 -> B4-s2-s2, MobileNet ->
B2-B2-s3-s1) show that after an unhelpful merge the search *advances to the
next adjacent pair* within the cluster rather than abandoning it — we
implement that semantics (stay on a pair after a successful merge so a
grown stage can keep absorbing, advance past an unhelpful one).

An exhaustive search over (pipeline x contiguous split) is provided for
small instances; tests use it to bound the heuristic's optimality gap.

Beyond the paper, this module also implements the *two-level* partition
DSE for multi-model co-serving (:func:`partition_search`): the cluster is
first partitioned into disjoint core *shares*, one per co-resident model,
then ``pipe_it_search`` balances each model's layers within its share —
"partition clusters across models, then partition layers within each
share".  Assignments are scored by an aggregate objective (weighted sum
of per-model Eq. 12 throughputs, with per-model SLO throughput floors);
:func:`exhaustive_partition` is the oracle for small instances.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from .pipeline import (
    Allocation,
    Pipeline,
    PipelinePlan,
    TimeMatrix,
    contiguous_allocation,
    enumerate_pipelines,
    stage_time,
)
from .plan import (
    SLO_PENALTY,
    Evaluation,
    FreqAssignment,
    MinThroughput,
    Plan,
    PowerCap,
    Share,
    SloP99,
    TailSlo,
    partition_parts,
    partition_rank_key,
    partition_score,
)
from .plan import evaluate as evaluate_plan
from .platform import HeteroPlatform, StageConfig
from .queueing import LatencyPrediction


def find_split(
    layers: Sequence[int],
    T: TimeMatrix,
    stage_a: StageConfig,
    stage_b: StageConfig,
    rule: str = "paper",
) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Algorithm 1: split ``layers`` (ordered) between adjacent stages.

    All work starts on the faster stage ``stage_a``; layers flow one at a
    time from the tail of ``stage_a`` to the head of ``stage_b``.

    rule="paper":  move while the donor stage would remain the bottleneck
      (the paper's exact condition — conservative: it can stop one move
      short of the best split).
    rule="minmax": move while the move strictly reduces
      max(t_left, t_right).  Because t_left is monotonically decreasing
      and t_right monotonically increasing in the number of moved layers,
      the max is unimodal and this greedy rule finds the *optimal*
      contiguous two-way split.  Beyond-paper improvement (DESIGN.md §2).
    """
    left = list(layers)
    right: List[int] = []
    t_left = stage_time(T, left, stage_a)
    t_right = 0.0
    while left:
        lj = left[-1]
        t_left_new = t_left - T[lj][stage_a]
        t_right_new = t_right + T[lj][stage_b]
        if rule == "paper":
            helpful = t_left_new > t_right_new
        elif rule == "minmax":
            helpful = max(t_left_new, t_right_new) < max(t_left, t_right)
        else:
            raise ValueError(f"unknown rule {rule!r}")
        if helpful:  # move of l_j is helpful
            left.pop()
            right.insert(0, lj)
            t_left, t_right = t_left_new, t_right_new
        else:  # further flow of workload will not be helpful
            break
    return tuple(left), tuple(right)


def work_flow(
    pipeline: Pipeline,
    layers: Sequence[int],
    T: TimeMatrix,
    max_rounds: int = 100,
    rule: str = "paper",
) -> Allocation:
    """Algorithm 2: iterative pairwise rebalancing until a fixed point."""
    p = pipeline.p
    alloc: List[Tuple[int, ...]] = [tuple(layers)] + [()] * (p - 1)
    old: Optional[List[Tuple[int, ...]]] = None
    rounds = 0
    while alloc != old and rounds < max_rounds:
        old = list(alloc)
        for i in range(p - 1):
            pool = tuple(alloc[i]) + tuple(alloc[i + 1])
            li, lj = find_split(
                pool, T, pipeline.stages[i], pipeline.stages[i + 1], rule=rule
            )
            alloc[i], alloc[i + 1] = li, lj
        rounds += 1
    return tuple(alloc)


def _plan(pipeline: Pipeline, alloc: Allocation) -> PipelinePlan:
    return PipelinePlan(pipeline=pipeline, allocation=alloc)


def merge_stage(
    layers: Sequence[int],
    platform: HeteroPlatform,
    T: TimeMatrix,
) -> PipelinePlan:
    """Algorithm 3: stage-configuration search by merging.

    Starts from an ``(H_B + H_s)``-stage pipeline of single cores (Big
    stages first), rebalances with work_flow, then greedily merges adjacent
    same-type stages while Eq. 14 holds.
    """
    stages: List[StageConfig] = []
    for ct in platform.core_types:
        stages.extend([(ct.name, 1)] * ct.count)
    pipeline = Pipeline(stages=tuple(stages))
    alloc = work_flow(pipeline, layers, T)

    def eq14_merge_helpful(i: int) -> bool:
        """Eq. 14: merged stage beats the slower of the two originals."""
        (ta, ca), (tb, cb) = pipeline.stages[i], pipeline.stages[i + 1]
        merged: StageConfig = (ta, ca + cb)
        t_merged = stage_time(T, alloc[i] + alloc[i + 1], merged)
        t_i = stage_time(T, alloc[i], pipeline.stages[i])
        t_j = stage_time(T, alloc[i + 1], pipeline.stages[i + 1])
        return t_merged < max(t_i, t_j)

    i = 0
    while i < pipeline.p - 1:
        (ta, _), (tb, _) = pipeline.stages[i], pipeline.stages[i + 1]
        if ta != tb:  # cluster boundary: never mix core types in a stage
            i += 1
            continue
        if eq14_merge_helpful(i):
            new_stages = list(pipeline.stages)
            merged = (ta, new_stages[i][1] + new_stages[i + 1][1])
            new_stages[i : i + 2] = [merged]
            pipeline = Pipeline(stages=tuple(new_stages))
            alloc = work_flow(pipeline, layers, T)
            # stay at i: the grown stage may keep absorbing its neighbour
        else:
            i += 1

    # Drop stages that received no layers (their cores stay idle; the
    # paper's final configurations never contain empty stages).
    kept = [
        (st, al)
        for st, al in zip(pipeline.stages, alloc)
        if al
    ]
    pipeline = Pipeline(stages=tuple(st for st, _ in kept))
    alloc = tuple(al for _, al in kept)
    return _plan(pipeline, alloc)


def _sweep_plans(
    n_layers: int, platform: HeteroPlatform, T: TimeMatrix
) -> List[PipelinePlan]:
    """The sweep-mode candidate set: every pipeline (plus the
    single-cluster degenerates), work_flow(minmax)-balanced, empty stages
    dropped.  Shared by :func:`pipeline_sweep` (throughput ranking) and
    the power-aware search (its own objective) so both always explore the
    SAME design space."""
    layers = list(range(n_layers))
    plans: List[PipelinePlan] = []
    h = platform.total_cores()
    for p in range(1, h + 1):
        pipes = (
            enumerate_pipelines(platform, p)
            if p > 1
            else [Pipeline(stages=((ct.name, ct.count),)) for ct in platform.core_types]
        )
        for pipeline in pipes:
            alloc = work_flow(pipeline, layers, T, rule="minmax")
            kept = [(st, al) for st, al in zip(pipeline.stages, alloc) if al]
            plans.append(
                _plan(
                    Pipeline(stages=tuple(st for st, _ in kept)),
                    tuple(al for _, al in kept),
                )
            )
    return plans


def pipeline_sweep(
    n_layers: int,
    platform: HeteroPlatform,
    T: TimeMatrix,
) -> PipelinePlan:
    """Beyond-paper mode: the number of distinct *pipelines* is small
    (Eq. 1 gives 64 on the 4+4 platform) — the exponential blow-up is in
    the split points, which ``work_flow`` resolves heuristically.  Running
    work_flow on every pipeline is cheap and never worse than Algorithm 3
    (recorded in DESIGN.md §2 / EXPERIMENTS.md §Perf as an improvement).

    Candidates are ranked through the unified evaluator (``core.plan``);
    ``max`` keeps the first of rank-equal candidates, matching the
    pre-IR ``tp > best_tp`` loop exactly."""
    return max(
        _sweep_plans(n_layers, platform, T),
        key=lambda plan: evaluate_plan(Plan.from_legacy(plan), T, platform).rank,
    )


def pipe_it_search(
    n_layers: int,
    platform: HeteroPlatform,
    T: TimeMatrix,
    mode: str = "merge",
    *,
    power_cap_w: Optional[float] = None,
    objective: str = "throughput",
    slo_p99_ms: Optional[float] = None,
    arrival_rate: Optional[float] = None,
) -> PipelinePlan:
    """The Pipe-it DSE entry point (paper §VI).

    mode="merge"  — the paper's Algorithm 3 (faithful).
    mode="sweep"  — beyond-paper work_flow-over-all-pipelines.
    mode="best"   — run both, return the higher-throughput plan.

    With ``power_cap_w`` set (watts of modeled average active power) or
    ``objective="throughput_per_watt"``, the search gains the DVFS
    dimension and returns a :class:`PowerAwarePlan` (plan + per-stage OPP
    assignment) instead of a bare :class:`PipelinePlan` — see
    :func:`power_aware_search`.

    With ``slo_p99_ms``/``arrival_rate`` set (an end-to-end p99 budget in
    ms and the open-loop Poisson rate in img/s), candidates are ranked by
    SLO feasibility BEFORE throughput — the serving regime, where the
    throughput-optimal deep pipeline is often the tail-latency-worst plan
    — and the result is a :class:`SloPlan` (see
    :func:`latency_aware_search`).  Combined with the power arguments the
    SLO becomes an extra feasibility constraint on the DVFS search (a
    :class:`PowerAwarePlan` whose clocks never drop below what the tail
    budget needs).
    """
    if slo_p99_ms is not None and arrival_rate is None:
        raise ValueError("slo_p99_ms requires arrival_rate")
    if power_cap_w is not None or objective != "throughput":
        return power_aware_search(
            n_layers, platform, T, mode=mode,
            power_cap_w=power_cap_w, objective=objective,
            slo_p99_s=None if slo_p99_ms is None else slo_p99_ms / 1e3,
            arrival_rate=arrival_rate,
        )
    if slo_p99_ms is not None:
        return latency_aware_search(
            n_layers, platform, T,
            arrival_rate=arrival_rate, slo_p99_s=slo_p99_ms / 1e3, mode=mode,
        )
    if mode == "merge":
        return merge_stage(list(range(n_layers)), platform, T)
    if mode == "sweep":
        return pipeline_sweep(n_layers, platform, T)
    if mode == "best":
        a = merge_stage(list(range(n_layers)), platform, T)
        b = pipeline_sweep(n_layers, platform, T)
        ra = evaluate_plan(Plan.from_legacy(a), T, platform).rank
        rb = evaluate_plan(Plan.from_legacy(b), T, platform).rank
        return a if ra >= rb else b
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# Frequency- and power-aware planning: the DVFS dimension of the DSE
# ---------------------------------------------------------------------------
#
# The paper plans only for peak img/s at an implicit fixed clock; edge
# deployments plan under power/thermal envelopes (Synergy 1804.00706, PICO
# 2206.08662).  This section adds per-stage frequency assignment on top of
# the (pipeline x allocation) search: every stage picks an OPP from its
# cluster's table (platform.py), stage times scale by (f_max/f)^kappa, and
# plans are ranked by `objective` subject to an average-power cap
#
#     P_avg = sum_i P_i(f_i) * t_i(f_i) / max_i t_i(f_i)
#
# (each stage is busy t_i out of every cycle max_i t_i; idle power is not
# modeled — DESIGN.md §7).  The assignment search is exact without being
# exhaustive: for any target cycle time tau, the power-minimal assignment
# clocks each stage at the LOWEST OPP meeting tau (power is monotone in f),
# and the optimal tau equals some stage's time at some OPP — so scanning
# the n_stages x n_OPP candidate taus covers the whole Pareto frontier.
# "Race to idle" (everything at f_max) is always emitted as a candidate;
# under the convex V(f) curve it loses to pace-to-bottleneck on energy,
# which is exactly the trade the benchmark quantifies.

#: "throughput" — max img/s (under the cap); "throughput_per_watt" — max
#: img/s per modeled watt; "min_energy" — min energy per image subject to
#: ``min_throughput`` (the iso-throughput / SLO-rate deployment: pace every
#: stage to the demand, not to the silicon's peak).
POWER_OBJECTIVES = ("throughput", "throughput_per_watt", "min_energy")


@dataclasses.dataclass(frozen=True)
class PowerAwarePlan:
    """A pipeline plan plus its per-stage frequency (DVFS) assignment."""

    plan: PipelinePlan
    stage_freqs: FreqAssignment
    throughput: float  # Eq. 12 at the assigned frequencies (img/s)
    avg_power_w: float  # modeled average active power over a cycle
    energy_per_image_j: float  # sum_i P_i * t_i
    objective: float  # the ranked score under `objective_name`
    objective_name: str = "throughput"
    power_cap_w: Optional[float] = None
    feasible: bool = True  # avg_power_w <= power_cap_w (True when uncapped)
    # SLO dimension (None when the search was latency-blind): predicted
    # end-to-end p99 at the assigned clocks under Poisson arrivals at
    # ``arrival_rate`` (core.queueing), and the budget it was held to.
    # ``feasible`` additionally requires p99_s <= slo_p99_s when set.
    p99_s: Optional[float] = None
    slo_p99_s: Optional[float] = None
    arrival_rate: Optional[float] = None
    # The unified-evaluator record this shim was scored by (core.plan);
    # None only on hand-constructed instances.
    evaluation: Optional[Evaluation] = dataclasses.field(
        default=None, compare=False, repr=False
    )

    def notation(self) -> str:
        freqs = "/".join(
            "fix" if f is None else f"{f / 1e9:.2f}GHz" for f in self.stage_freqs
        )
        return f"{self.plan.notation()}  @ {freqs}"

    def plan_ir(self) -> Plan:
        """This point of the design space as the unified IR."""
        return Plan.from_legacy(self)


def stage_times_at(
    plan: PipelinePlan,
    T: TimeMatrix,
    platform: HeteroPlatform,
    stage_freqs: FreqAssignment,
) -> List[float]:
    """Per-stage service times with each stage at its assigned OPP."""
    if len(stage_freqs) != plan.pipeline.p:
        raise ValueError(
            f"{len(stage_freqs)} stage_freqs for {plan.pipeline.p} stages"
        )
    return [
        stage_time(T, layers, stage) * platform.freq_scale(stage[0], f)
        for layers, stage, f in zip(
            plan.allocation, plan.pipeline.stages, stage_freqs
        )
    ]


def max_freqs(plan: PipelinePlan, platform: HeteroPlatform) -> FreqAssignment:
    """The race-to-idle assignment: every stage at its cluster's top OPP."""
    return tuple(
        (platform.freq_levels(ct) or (None,))[-1]
        for ct, _ in plan.pipeline.stages
    )


def evaluate_frequencies(
    plan: PipelinePlan,
    T: TimeMatrix,
    platform: HeteroPlatform,
    stage_freqs: FreqAssignment,
    power_cap_w: Optional[float] = None,
    objective: str = "throughput",
    min_throughput: Optional[float] = None,
    slo_p99_s: Optional[float] = None,
    arrival_rate: Optional[float] = None,
) -> PowerAwarePlan:
    """Score one (plan, frequency assignment) point of the design space.

    With ``slo_p99_s``/``arrival_rate`` set, the M/D/1 tail model
    (core.queueing) predicts end-to-end p99 at these clocks — base latency
    (sum of scaled stage times) plus the bottleneck's p99 queue wait at
    the offered rate — and folds it into ``feasible``.  This is what
    makes SLO-aware DVFS "never down-clock into an SLO violation": a
    slower OPP that still meets the cap but pushes predicted p99 past the
    budget is simply infeasible.
    """
    if objective not in POWER_OBJECTIVES:
        raise ValueError(
            f"unknown objective {objective!r}; one of {POWER_OBJECTIVES}"
        )
    if (slo_p99_s is None) != (arrival_rate is None):
        raise ValueError("slo_p99_s and arrival_rate must be set together")
    if len(stage_freqs) != plan.pipeline.p:
        raise ValueError(
            f"{len(stage_freqs)} stage_freqs for {plan.pipeline.p} stages"
        )
    constraints = []
    if power_cap_w is not None:
        constraints.append(PowerCap(power_cap_w))
    if min_throughput is not None:
        constraints.append(MinThroughput(min_throughput))
    if slo_p99_s is not None:
        constraints.append(SloP99(slo_p99_s))
    ev = evaluate_plan(
        Plan(
            stages=plan.pipeline.stages,
            allocation=plan.allocation,
            stage_freqs=tuple(stage_freqs),
        ),
        T,
        platform,
        objective=objective,
        constraints=constraints,
        arrival_rate=arrival_rate,
    )
    m = ev.metrics
    return PowerAwarePlan(
        plan=plan,
        stage_freqs=tuple(stage_freqs),
        throughput=m.throughput,
        avg_power_w=m.avg_power_w,
        energy_per_image_j=m.energy_per_image_j,
        objective=ev.score[0],
        objective_name=objective,
        power_cap_w=power_cap_w,
        feasible=ev.feasible,
        p99_s=m.p99_s if slo_p99_s is not None else None,
        slo_p99_s=slo_p99_s,
        arrival_rate=arrival_rate,
        evaluation=ev,
    )


def _require_power_model(
    platform: HeteroPlatform, power_cap_w: Optional[float]
) -> None:
    """A cap against a platform that models zero power would be *trivially*
    satisfied — every plan draws 0 modeled watts — which silently tells the
    caller their envelope is enforced when it was never evaluated."""
    if power_cap_w is not None and platform.max_power_w() <= 0.0:
        raise ValueError(
            f"power_cap_w={power_cap_w} on platform {platform.name!r}, which "
            "models no power (no OPP tables / zero capacitance) — the cap "
            "would be vacuously met; use a DVFS platform like hikey970()"
        )


def _power_rank_key(
    p: PowerAwarePlan,
    power_cap_w: Optional[float] = None,
    min_throughput: Optional[float] = None,
):
    """Feasible beats infeasible; among feasible, best objective then
    least power.  Infeasible candidates rank by WHY they are infeasible:
    a cap violation is a safety problem (least power first — closest to
    the envelope), but a missed throughput floor with the cap intact
    means demand outstrips capacity — best effort there is to run as
    FAST as the cap allows, not to idle at minimum clocks.

    Since the plan-IR migration this ordering lives in ``core.plan``
    (severity-0 :class:`~.plan.PowerCap` vs severity-1
    :class:`~.plan.MinThroughput`/:class:`~.plan.SloP99` tails); this
    shim returns the stored :class:`~.plan.Evaluation` rank and only
    reconstructs the key for hand-built instances."""
    if p.evaluation is not None:
        return p.evaluation.rank
    if p.feasible:
        return (2, p.objective, -p.avg_power_w)
    cap_ok = power_cap_w is None or p.avg_power_w <= power_cap_w * (1 + 1e-9)
    if cap_ok:  # only the min_throughput floor is missed
        return (1, p.throughput, -p.avg_power_w)
    return (0, -p.avg_power_w, p.objective)


def assign_frequencies(
    plan: PipelinePlan,
    T: TimeMatrix,
    platform: HeteroPlatform,
    power_cap_w: Optional[float] = None,
    objective: str = "throughput",
    min_throughput: Optional[float] = None,
    slo_p99_s: Optional[float] = None,
    arrival_rate: Optional[float] = None,
) -> PowerAwarePlan:
    """Optimal per-stage OPP assignment for a fixed (pipeline, allocation).

    Scans the candidate cycle times (every stage's time at every OPP —
    the only values the optimum can take) and, per candidate tau, clocks
    each stage at the lowest OPP meeting tau (slack-matched: a stage
    never clocks above what the bottleneck needs).  Exact versus
    :func:`exhaustive_frequency_assignment` because per-stage power is
    monotone in f and stages are independent given tau.  The race-to-idle
    (all-f_max) assignment is always a candidate; ``min_throughput`` adds
    the iso-throughput floor (pace to the demand rate, not the silicon).
    """
    _require_power_model(platform, power_cap_w)
    base = plan.stage_times(T)
    per_stage: List[List[Tuple[Optional[float], float]]] = []
    for i, (ct, _n) in enumerate(plan.pipeline.stages):
        freqs = platform.freq_levels(ct) or (None,)
        per_stage.append(
            [(f, base[i] * platform.freq_scale(ct, f)) for f in freqs]
        )
    taus = sorted({t for opts in per_stage for _f, t in opts})
    candidates: List[PowerAwarePlan] = [
        evaluate_frequencies(
            plan, T, platform, max_freqs(plan, platform),
            power_cap_w, objective, min_throughput,
            slo_p99_s, arrival_rate,
        )  # race-to-idle
    ]
    miss = object()  # distinct from None: a fixed-clock stage's OPP IS None
    for tau in taus:
        freqs: List[Optional[float]] = []
        for opts in per_stage:
            pick = next(  # ascending f <=> descending t: first hit = lowest f
                (f for f, t in opts if t <= tau * (1 + 1e-12)), miss
            )
            if pick is miss:  # tau faster than this stage's f_max
                break
            freqs.append(pick)
        if len(freqs) != plan.pipeline.p:
            continue
        candidates.append(
            evaluate_frequencies(
                plan, T, platform, tuple(freqs),
                power_cap_w, objective, min_throughput,
                slo_p99_s, arrival_rate,
            )
        )
    return max(
        candidates,
        key=lambda c: _power_rank_key(c, power_cap_w, min_throughput),
    )


def exhaustive_frequency_assignment(
    plan: PipelinePlan,
    T: TimeMatrix,
    platform: HeteroPlatform,
    power_cap_w: Optional[float] = None,
    objective: str = "throughput",
    min_throughput: Optional[float] = None,
    slo_p99_s: Optional[float] = None,
    arrival_rate: Optional[float] = None,
) -> PowerAwarePlan:
    """Oracle: every per-stage OPP combination (|OPP|^p — small instances
    only); tests bound :func:`assign_frequencies` against it."""
    per_stage = [
        platform.freq_levels(ct) or (None,) for ct, _ in plan.pipeline.stages
    ]
    best: Optional[PowerAwarePlan] = None
    for combo in itertools.product(*per_stage):
        cand = evaluate_frequencies(
            plan, T, platform, combo, power_cap_w, objective, min_throughput,
            slo_p99_s, arrival_rate,
        )
        if best is None or _power_rank_key(
            cand, power_cap_w, min_throughput
        ) > _power_rank_key(best, power_cap_w, min_throughput):
            best = cand
    assert best is not None
    return best


def _candidate_plans(
    n_layers: int, platform: HeteroPlatform, T: TimeMatrix, mode: str
) -> List[PipelinePlan]:
    """The plan candidates the selected DSE mode would consider, surfaced
    so the power-aware search can re-rank them under its own objective
    (the throughput-optimal pipeline is NOT always the capped or
    per-watt-optimal one — e.g. a cap may favour fewer, slower stages)."""
    if mode not in ("merge", "sweep", "best"):
        raise ValueError(f"unknown mode {mode!r}")
    plans: List[PipelinePlan] = []
    if mode in ("merge", "best"):
        plans.append(merge_stage(list(range(n_layers)), platform, T))
    if mode in ("sweep", "best"):
        plans.extend(_sweep_plans(n_layers, platform, T))
    seen = set()
    unique = []
    for pl in plans:
        key = (pl.pipeline.stages, pl.allocation)
        if key not in seen:
            seen.add(key)
            unique.append(pl)
    return unique


def power_aware_search(
    n_layers: int,
    platform: HeteroPlatform,
    T: TimeMatrix,
    mode: str = "best",
    power_cap_w: Optional[float] = None,
    objective: str = "throughput",
    min_throughput: Optional[float] = None,
    slo_p99_s: Optional[float] = None,
    arrival_rate: Optional[float] = None,
) -> PowerAwarePlan:
    """The DVFS-extended DSE entry point: (pipeline x allocation x per-stage
    OPP) ranked by ``objective`` under an average-power cap.

    ``T`` stays the 2-D f_max time matrix (the factored form of the
    (layer, config, freq) matrix — frequency enters via the platform's
    ``freq_scale``, exactly how the calibrated corrections compose).
    Returns the best feasible :class:`PowerAwarePlan`; if no candidate
    meets the cap even fully down-clocked, the least-power assignment is
    returned with ``feasible=False`` (best effort under overload) — the
    caller decides whether to shed load instead.
    """
    _require_power_model(platform, power_cap_w)
    best: Optional[PowerAwarePlan] = None
    for pl in _candidate_plans(n_layers, platform, T, mode):
        cand = assign_frequencies(
            pl, T, platform, power_cap_w, objective, min_throughput,
            slo_p99_s, arrival_rate,
        )
        if best is None or _power_rank_key(
            cand, power_cap_w, min_throughput
        ) > _power_rank_key(best, power_cap_w, min_throughput):
            best = cand
    assert best is not None
    return best


# ---------------------------------------------------------------------------
# SLO-aware planning: rank by tail-latency feasibility before throughput
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SloPlan:
    """A plan ranked under an end-to-end p99 SLO at an offered rate.

    ``feasible`` means the queueing model predicts p99 within
    ``headroom * slo_p99_s`` — the margin absorbs model error (the M/D/1
    reduction over-/under-shoots the simulator by up to ~15% near high
    utilization; tests/test_queueing.py pins the band) so a plan the
    search calls feasible is not shown violating the SLO by the
    simulator.
    """

    plan: PipelinePlan
    prediction: LatencyPrediction
    throughput: float  # Eq. 12 saturation capacity (img/s)
    arrival_rate: float
    slo_p99_s: float
    headroom: float
    feasible: bool
    # The unified-evaluator record this shim was scored by (core.plan);
    # None only on hand-constructed instances.
    evaluation: Optional[Evaluation] = dataclasses.field(
        default=None, compare=False, repr=False
    )

    def plan_ir(self) -> Plan:
        """This point of the design space as the unified IR."""
        return Plan.from_legacy(self)

    def notation(self) -> str:
        p99 = (
            "inf" if not self.prediction.stable
            else f"{self.prediction.p99_s * 1e3:.1f}ms"
        )
        verdict = "<=" if self.feasible else ">"
        return (
            f"{self.plan.notation()}  @ p99~{p99} "
            f"{verdict} {self.slo_p99_s * 1e3:.1f}ms SLO"
        )


def _slo_rank_key(s: SloPlan):
    """Feasibility floor first (the ``partition_search`` lexicographic
    idiom): among feasible plans, most throughput, then lowest p99; among
    stable-but-over-budget plans, closest to the budget; unstable plans
    last, least-overloaded first.

    Since the plan-IR migration this ordering lives in ``core.plan``
    (the ``"slo_throughput"`` objective + :class:`~.plan.TailSlo`
    constraint); this shim returns the stored
    :class:`~.plan.Evaluation` rank and only reconstructs the key for
    hand-built instances."""
    if s.evaluation is not None:
        return s.evaluation.rank
    if s.feasible:
        return (2, s.throughput, -s.prediction.p99_s)
    if s.prediction.stable:
        return (1, -s.prediction.p99_s, s.throughput)
    return (0, -s.prediction.utilization, s.throughput)


def latency_aware_search(
    n_layers: int,
    platform: HeteroPlatform,
    T: TimeMatrix,
    *,
    arrival_rate: float,
    slo_p99_s: float,
    mode: str = "best",
    headroom: float = 0.9,
    boundary_bytes: Optional[Sequence[int]] = None,
) -> SloPlan:
    """SLO-first DSE over the same candidate plans the throughput search
    considers, plus every single-stage vocabulary config (the low-latency
    end of the space a saturation search never visits).

    The throughput-optimal deep pipeline maximises Eq. 12 but pays its
    depth in base latency (every stage time + boundary hop is on the
    critical path of EVERY image); under an open-loop rate with a p99
    budget, a shallower plan with a little less capacity is often the
    only feasible choice.  Candidates are ranked feasibility-first (see
    :func:`_slo_rank_key`); if nothing fits the budget the best-effort
    plan is returned with ``feasible=False`` — the caller decides whether
    to shed load or relax the SLO.
    """
    if arrival_rate <= 0.0:
        raise ValueError(f"arrival_rate {arrival_rate} <= 0")
    if slo_p99_s <= 0.0:
        raise ValueError(f"slo_p99_s {slo_p99_s} <= 0")
    if not 0.0 < headroom <= 1.0:
        raise ValueError(f"headroom {headroom} outside (0, 1]")
    plans = _candidate_plans(n_layers, platform, T, mode)
    all_layers = tuple(range(n_layers))
    seen = {(pl.pipeline.stages, pl.allocation) for pl in plans}
    for stage in platform.stage_vocabulary():  # p = 1 candidates
        pl = _plan(Pipeline(stages=(stage,)), (all_layers,))
        if (pl.pipeline.stages, pl.allocation) not in seen:
            plans.append(pl)
    constraints = (TailSlo(slo_p99_s, headroom=headroom),)
    best: Optional[SloPlan] = None
    for pl in plans:
        ev = evaluate_plan(
            Plan.from_legacy(pl),
            T,
            platform,
            objective="slo_throughput",
            constraints=constraints,
            arrival_rate=arrival_rate,
            boundary_bytes=boundary_bytes,
        )
        cand = SloPlan(
            plan=pl,
            prediction=ev.metrics.prediction,
            throughput=ev.metrics.throughput,
            arrival_rate=arrival_rate,
            slo_p99_s=slo_p99_s,
            headroom=headroom,
            feasible=ev.feasible,
            evaluation=ev,
        )
        if best is None or _slo_rank_key(cand) > _slo_rank_key(best):
            best = cand
    assert best is not None
    return best


# ---------------------------------------------------------------------------
# Exhaustive reference search (small instances only; used by tests/benches)
# ---------------------------------------------------------------------------

def exhaustive_two_way_split(
    layers: Sequence[int],
    T: TimeMatrix,
    stage_a: StageConfig,
    stage_b: StageConfig,
) -> Tuple[Tuple[Tuple[int, ...], Tuple[int, ...]], float]:
    """Brute-force optimal contiguous two-way split of ``layers``.

    Tries every prefix/suffix cut (the only splits Algorithm 1 can emit)
    and returns ``((left, right), bottleneck)`` minimising
    ``max(T_left^a, T_right^b)``.  O(n^2); reference oracle for the
    ``find_split`` property tests."""
    ordered = list(layers)
    best: Optional[Tuple[Tuple[int, ...], Tuple[int, ...]]] = None
    best_t = float("inf")
    for k in range(len(ordered) + 1):
        left, right = tuple(ordered[:k]), tuple(ordered[k:])
        t = max(stage_time(T, left, stage_a), stage_time(T, right, stage_b))
        if t < best_t:
            best, best_t = (left, right), t
    assert best is not None
    return best, best_t

def _exhaustive_plan(
    n_layers: int, platform: HeteroPlatform, T: TimeMatrix
) -> PipelinePlan:
    """True optimum over EVERY executable plan on ``platform``: all
    partial-cluster pipelines (``enumerate_pipelines(allow_partial=True)``
    — the closure of what merge/sweep can emit after dropping empty
    stages) x every contiguous non-empty layer split, plus every
    single-stage vocabulary config.  Exponential; the inner oracle of
    :func:`exhaustive_partition` and of small-instance
    :func:`partition_search` shares."""
    best: Optional[PipelinePlan] = None
    best_tp = -1.0
    for stage in platform.stage_vocabulary():  # p = 1: any (ct, c) config
        plan = _plan(Pipeline(stages=(stage,)), (tuple(range(n_layers)),))
        tp = plan.throughput(T)
        if tp > best_tp:
            best, best_tp = plan, tp
    top = min(platform.total_cores(), n_layers)
    for p in range(2, top + 1):
        for pipeline in enumerate_pipelines(platform, p, allow_partial=True):
            for cuts in itertools.combinations(range(1, n_layers), p - 1):
                alloc = contiguous_allocation(cuts, n_layers, p)
                plan = _plan(pipeline, alloc)
                tp = plan.throughput(T)
                if tp > best_tp:
                    best, best_tp = plan, tp
    assert best is not None
    return best


# ---------------------------------------------------------------------------
# Two-level partition DSE: clusters across models, layers within each share
# ---------------------------------------------------------------------------

# Share and SLO_PENALTY live in core.plan since the IR migration; both
# remain importable from here (re-exported above) for compatibility.


def _nonneg_compositions(total: int, parts: int) -> List[Tuple[int, ...]]:
    if parts == 1:
        return [(total,)]
    out = []
    for first in range(total + 1):
        for rest in _nonneg_compositions(total - first, parts - 1):
            out.append((first, *rest))
    return out


def enumerate_shares(platform: HeteroPlatform, n_models: int) -> List[Tuple[Share, ...]]:
    """All ways to partition the platform's clusters into ``n_models``
    disjoint core shares.

    Every core is assigned to some model (the paper never idles silicon
    at the cluster level; a model's *inner* DSE may still leave share
    cores unused) and every model receives at least one core.  Returns,
    per assignment, one ``((core_type, count), ...)`` share per model —
    hashable, zero-count entries elided."""
    if n_models < 1:
        raise ValueError("need >= 1 model")
    if n_models > platform.total_cores():
        raise ValueError(
            f"{n_models} models cannot each get a core on "
            f"{platform.total_cores()}-core {platform.name!r}"
        )
    per_ct = [
        _nonneg_compositions(ct.count, n_models) for ct in platform.core_types
    ]
    names = [ct.name for ct in platform.core_types]
    out: List[Tuple[Share, ...]] = []
    for combo in itertools.product(*per_ct):
        shares = []
        for mi in range(n_models):
            share = tuple(
                (names[ci], combo[ci][mi])
                for ci in range(len(names))
                if combo[ci][mi] > 0
            )
            shares.append(share)
        if all(shares):  # every model got >= 1 core
            out.append(tuple(shares))
    return out


def partition_objective(
    throughputs: Sequence[float],
    weights: Optional[Sequence[float]] = None,
    slo_rates: Optional[Sequence[float]] = None,
    fairness: str = "sum",
) -> float:
    """Aggregate co-serving score for one cluster-share assignment.

    fairness="sum"     — utilitarian: ``sum_m w_m * tp_m``.  Maximises
      machine-wide goodput; right when per-model demand is open-ended.
    fairness="max-min" — egalitarian: ``min_m w_m * tp_m``.  Maximises
      the worst model's (weighted) rate; right when every model must
      sustain comparable demand (set ``w_m = 1/demand_m`` to equalise
      heterogeneous demands).

    Either way, each relative SLO shortfall is charged
    :data:`SLO_PENALTY` in the returned scalar.  The *searches* rank
    assignments lexicographically via :func:`_objective_parts` —
    feasibility first, then least total shortfall, then score — so a
    feasible assignment beats every infeasible one even when throughputs
    are large enough to swamp the finite penalty; this scalar is the
    reported/compared form of that same ordering.

    Since the IR migration both pieces live in ``core.plan``
    (:func:`~.plan.partition_parts` with the :data:`~.plan.FAIRNESS`
    registry, scalarised by :func:`~.plan.partition_score`); this
    function is the compatibility name."""
    return partition_score(throughputs, weights, slo_rates, fairness)


def _objective_parts(
    throughputs: Sequence[float],
    weights: Optional[Sequence[float]],
    slo_rates: Optional[Sequence[float]],
    fairness: str,
) -> Tuple[float, float]:
    """(score, total relative SLO shortfall) — shim over core.plan."""
    return partition_parts(throughputs, weights, slo_rates, fairness)


@dataclasses.dataclass(frozen=True)
class ModelPlan:
    """One model's slice of a partition: its core share and inner plan."""

    name: str
    share: HeteroPlatform
    plan: PipelinePlan
    throughput: float  # predicted Eq. 12 rate on this model's time matrix
    # DVFS assignment for this model's stages (power-aware partitions only)
    power: Optional[PowerAwarePlan] = None

    def notation(self) -> str:
        return f"{self.name}@{self.plan.notation()}"

    def plan_ir(self) -> Plan:
        """This model's slice as the unified IR (model + share + clocks)."""
        return Plan.from_legacy(self)


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """A full co-serving assignment: disjoint shares + per-model plans."""

    assignments: Tuple[ModelPlan, ...]
    objective: float
    feasible: bool  # every model met its SLO throughput floor
    total_power_w: float = 0.0  # summed modeled avg power (power-aware only)

    @property
    def names(self) -> List[str]:
        return [a.name for a in self.assignments]

    def __getitem__(self, name: str) -> ModelPlan:
        for a in self.assignments:
            if a.name == name:
                return a
        raise KeyError(name)

    def throughputs(self) -> Dict[str, float]:
        return {a.name: a.throughput for a in self.assignments}

    def plans(self) -> Dict[str, PipelinePlan]:
        return {a.name: a.plan for a in self.assignments}

    def plan_irs(self) -> Tuple[Plan, ...]:
        """Every model's slice as the unified IR, in assignment order."""
        return tuple(a.plan_ir() for a in self.assignments)

    def notation(self) -> str:
        return " | ".join(a.notation() for a in self.assignments)


def _search_over_shares(
    names: Sequence[str],
    Ts: Sequence[TimeMatrix],
    platform: HeteroPlatform,
    weights: Sequence[float],
    slo_rates: Sequence[float],
    fairness: str,
    inner,
) -> PartitionPlan:
    """Rank every cluster-share assignment by the aggregate objective.

    ``inner(model_index, share) -> PipelinePlan | PowerAwarePlan`` supplies
    the per-share layer (and, power-aware, frequency) search; memoized per
    (model, share) because the same share recurs across many assignments."""
    cache: Dict[
        Tuple[int, Share],
        Tuple[HeteroPlatform, PipelinePlan, float, Optional[PowerAwarePlan]],
    ] = {}

    def solve(mi: int, share: Share):
        key = (mi, share)
        if key not in cache:
            sub = platform.subset(dict(share))
            result = inner(mi, sub)
            if isinstance(result, PowerAwarePlan):
                cache[key] = (sub, result.plan, result.throughput, result)
            else:
                cache[key] = (sub, result, result.throughput(Ts[mi]), None)
        return cache[key]

    best: Optional[PartitionPlan] = None
    best_key = None
    for assignment in enumerate_shares(platform, len(names)):
        solved = [solve(mi, share) for mi, share in enumerate(assignment)]
        tps = [tp for _, _, tp, _ in solved]
        score, shortfall = _objective_parts(tps, weights, slo_rates, fairness)
        # power-infeasible shares count like SLO misses: a feasible
        # assignment (cap met everywhere) beats any infeasible one
        power_ok = all(pp is None or pp.feasible for _, _, _, pp in solved)
        # lexicographic: feasibility beats any score, then least miss,
        # then score — immune to throughputs outscaling the penalty
        # (the shared core.plan idiom)
        key = partition_rank_key(score, shortfall, power_ok)
        if best_key is None or key > best_key:
            best_key = key
            best = PartitionPlan(
                assignments=tuple(
                    ModelPlan(
                        name=nm, share=sub, plan=plan, throughput=tp, power=pp
                    )
                    for nm, (sub, plan, tp, pp) in zip(names, solved)
                ),
                objective=score - SLO_PENALTY * shortfall,
                feasible=shortfall == 0.0 and power_ok,
                total_power_w=sum(
                    pp.avg_power_w for _, _, _, pp in solved if pp is not None
                ),
            )
    assert best is not None
    return best


def _normalize_instances(
    instances: Mapping[str, TimeMatrix],
    weights: Optional[Mapping[str, float]],
    slo_rates: Optional[Mapping[str, float]],
):
    names = list(instances)
    if not names:
        raise ValueError("need >= 1 model instance")
    # a typo'd model name must not silently drop a weight or SLO floor
    for label, mapping in (("weights", weights), ("slo_rates", slo_rates)):
        unknown = [k for k in (mapping or {}) if k not in instances]
        if unknown:
            raise ValueError(
                f"{label} name unknown models {unknown}; instances are {names}"
            )
    Ts = [instances[nm] for nm in names]
    w = [float((weights or {}).get(nm, 1.0)) for nm in names]
    slo = [float((slo_rates or {}).get(nm, 0.0)) for nm in names]
    return names, Ts, w, slo


def partition_search(
    instances: Mapping[str, TimeMatrix],
    platform: HeteroPlatform,
    *,
    weights: Optional[Mapping[str, float]] = None,
    slo_rates: Optional[Mapping[str, float]] = None,
    mode: str = "best",
    exact_threshold: int = 8,
    fairness: str = "sum",
    power_cap_w: Optional[float] = None,
    power_objective: str = "throughput",
) -> PartitionPlan:
    """Two-level DSE for multi-model co-serving.

    Level 1 enumerates cluster-share assignments (exact — the space is
    small, Eq. 1-style counting over models instead of stages); level 2
    reuses :func:`pipe_it_search` to balance each model's layers within
    its share.  Models whose layer count is <= ``exact_threshold`` also
    get the exhaustive inner search (cheap at that size), so on small
    instances the result provably matches :func:`exhaustive_partition`.

    ``instances`` maps model name -> that model's time matrix (order
    defines model order); ``weights``/``slo_rates``/``fairness`` feed
    :func:`partition_objective`.

    ``power_cap_w`` bounds the MACHINE's modeled average active power:
    each share receives a cap slice proportional to its all-max power
    envelope (shares are disjoint, so the slices sum to the cap), and the
    inner search gains the DVFS dimension (:func:`power_aware_search`)
    under that slice and ``power_objective``.  Per-model frequency
    assignments land on ``ModelPlan.power``; an assignment whose every
    share meets its slice outranks any that does not.
    """
    names, Ts, w, slo = _normalize_instances(instances, weights, slo_rates)
    _require_power_model(platform, power_cap_w)
    power_aware = power_cap_w is not None or power_objective != "throughput"
    machine_power = platform.max_power_w() if power_aware else 0.0

    def inner(mi: int, sub: HeteroPlatform):
        n = len(Ts[mi])
        if power_aware:
            cap = None
            if power_cap_w is not None and machine_power > 0.0:
                cap = power_cap_w * sub.max_power_w() / machine_power
            return power_aware_search(
                n, sub, Ts[mi], mode=mode,
                power_cap_w=cap, objective=power_objective,
            )
        plan = pipe_it_search(n, sub, Ts[mi], mode=mode)
        if n <= exact_threshold:
            exact = _exhaustive_plan(n, sub, Ts[mi])
            if exact.throughput(Ts[mi]) > plan.throughput(Ts[mi]):
                plan = exact
        return plan

    return _search_over_shares(names, Ts, platform, w, slo, fairness, inner)


def exhaustive_partition(
    instances: Mapping[str, TimeMatrix],
    platform: HeteroPlatform,
    *,
    weights: Optional[Mapping[str, float]] = None,
    slo_rates: Optional[Mapping[str, float]] = None,
    fairness: str = "sum",
) -> PartitionPlan:
    """Oracle for :func:`partition_search`: the same exact share
    enumeration, but with the exhaustive inner search everywhere.
    Exponential in layer count; small instances only (tests/benches)."""
    names, Ts, w, slo = _normalize_instances(instances, weights, slo_rates)

    def inner(mi: int, sub: HeteroPlatform) -> PipelinePlan:
        return _exhaustive_plan(len(Ts[mi]), sub, Ts[mi])

    return _search_over_shares(names, Ts, platform, w, slo, fairness, inner)


def exhaustive_search(
    n_layers: int,
    platform: HeteroPlatform,
    T: TimeMatrix,
    max_stages: Optional[int] = None,
) -> PipelinePlan:
    """Brute-force over every pipeline (Eq. 1) and every contiguous split
    (Eq. 2).  Exponential; only for validating the heuristic."""
    best: Optional[PipelinePlan] = None
    best_tp = -1.0
    h = platform.total_cores()
    top = min(max_stages or h, h, n_layers)
    for p in range(1, top + 1):
        if p == 1:
            # Degenerate single-stage "pipelines": best homogeneous cluster.
            for ct in platform.core_types:
                plan = _plan(
                    Pipeline(stages=((ct.name, ct.count),)),
                    (tuple(range(n_layers)),),
                )
                tp = plan.throughput(T)
                if tp > best_tp:
                    best, best_tp = plan, tp
            continue
        for pipeline in enumerate_pipelines(platform, p):
            for cuts in itertools.combinations(range(1, n_layers), p - 1):
                alloc = contiguous_allocation(cuts, n_layers, p)
                plan = _plan(pipeline, alloc)
                tp = plan.throughput(T)
                if tp > best_tp:
                    best, best_tp = plan, tp
    assert best is not None
    return best
