"""Design-space exploration — the paper's Algorithms 1-3, implemented
faithfully.

* :func:`find_split`  — Algorithm 1: water-flow split of a contiguous layer
  range between two adjacent stages.
* :func:`work_flow`   — Algorithm 2: iterate find_split over all adjacent
  stage pairs until the allocation stabilises.
* :func:`merge_stage` — Algorithm 3: start from one-core-per-stage and merge
  adjacent same-type stages while Eq. 14 predicts an improvement.

The paper's pseudocode for Algorithm 3 "break"s a cluster loop on the first
unhelpful merge; its worked examples (ResNet50 -> B4-s2-s2, MobileNet ->
B2-B2-s3-s1) show that after an unhelpful merge the search *advances to the
next adjacent pair* within the cluster rather than abandoning it — we
implement that semantics (stay on a pair after a successful merge so a
grown stage can keep absorbing, advance past an unhelpful one).

An exhaustive search over (pipeline x contiguous split) is provided for
small instances; tests use it to bound the heuristic's optimality gap.

Beyond the paper, this module also implements the *two-level* partition
DSE for multi-model co-serving (:func:`partition_search`): the cluster is
first partitioned into disjoint core *shares*, one per co-resident model,
then ``pipe_it_search`` balances each model's layers within its share —
"partition clusters across models, then partition layers within each
share".  Assignments are scored by an aggregate objective (weighted sum
of per-model Eq. 12 throughputs, with per-model SLO throughput floors);
:func:`exhaustive_partition` is the oracle for small instances.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from .pipeline import (
    Allocation,
    Pipeline,
    PipelinePlan,
    TimeMatrix,
    contiguous_allocation,
    enumerate_pipelines,
    stage_time,
)
from .platform import HeteroPlatform, StageConfig


def find_split(
    layers: Sequence[int],
    T: TimeMatrix,
    stage_a: StageConfig,
    stage_b: StageConfig,
    rule: str = "paper",
) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Algorithm 1: split ``layers`` (ordered) between adjacent stages.

    All work starts on the faster stage ``stage_a``; layers flow one at a
    time from the tail of ``stage_a`` to the head of ``stage_b``.

    rule="paper":  move while the donor stage would remain the bottleneck
      (the paper's exact condition — conservative: it can stop one move
      short of the best split).
    rule="minmax": move while the move strictly reduces
      max(t_left, t_right).  Because t_left is monotonically decreasing
      and t_right monotonically increasing in the number of moved layers,
      the max is unimodal and this greedy rule finds the *optimal*
      contiguous two-way split.  Beyond-paper improvement (DESIGN.md §2).
    """
    left = list(layers)
    right: List[int] = []
    t_left = stage_time(T, left, stage_a)
    t_right = 0.0
    while left:
        lj = left[-1]
        t_left_new = t_left - T[lj][stage_a]
        t_right_new = t_right + T[lj][stage_b]
        if rule == "paper":
            helpful = t_left_new > t_right_new
        elif rule == "minmax":
            helpful = max(t_left_new, t_right_new) < max(t_left, t_right)
        else:
            raise ValueError(f"unknown rule {rule!r}")
        if helpful:  # move of l_j is helpful
            left.pop()
            right.insert(0, lj)
            t_left, t_right = t_left_new, t_right_new
        else:  # further flow of workload will not be helpful
            break
    return tuple(left), tuple(right)


def work_flow(
    pipeline: Pipeline,
    layers: Sequence[int],
    T: TimeMatrix,
    max_rounds: int = 100,
    rule: str = "paper",
) -> Allocation:
    """Algorithm 2: iterative pairwise rebalancing until a fixed point."""
    p = pipeline.p
    alloc: List[Tuple[int, ...]] = [tuple(layers)] + [()] * (p - 1)
    old: Optional[List[Tuple[int, ...]]] = None
    rounds = 0
    while alloc != old and rounds < max_rounds:
        old = list(alloc)
        for i in range(p - 1):
            pool = tuple(alloc[i]) + tuple(alloc[i + 1])
            li, lj = find_split(
                pool, T, pipeline.stages[i], pipeline.stages[i + 1], rule=rule
            )
            alloc[i], alloc[i + 1] = li, lj
        rounds += 1
    return tuple(alloc)


def _plan(pipeline: Pipeline, alloc: Allocation) -> PipelinePlan:
    return PipelinePlan(pipeline=pipeline, allocation=alloc)


def merge_stage(
    layers: Sequence[int],
    platform: HeteroPlatform,
    T: TimeMatrix,
) -> PipelinePlan:
    """Algorithm 3: stage-configuration search by merging.

    Starts from an ``(H_B + H_s)``-stage pipeline of single cores (Big
    stages first), rebalances with work_flow, then greedily merges adjacent
    same-type stages while Eq. 14 holds.
    """
    stages: List[StageConfig] = []
    for ct in platform.core_types:
        stages.extend([(ct.name, 1)] * ct.count)
    pipeline = Pipeline(stages=tuple(stages))
    alloc = work_flow(pipeline, layers, T)

    def eq14_merge_helpful(i: int) -> bool:
        """Eq. 14: merged stage beats the slower of the two originals."""
        (ta, ca), (tb, cb) = pipeline.stages[i], pipeline.stages[i + 1]
        merged: StageConfig = (ta, ca + cb)
        t_merged = stage_time(T, alloc[i] + alloc[i + 1], merged)
        t_i = stage_time(T, alloc[i], pipeline.stages[i])
        t_j = stage_time(T, alloc[i + 1], pipeline.stages[i + 1])
        return t_merged < max(t_i, t_j)

    i = 0
    while i < pipeline.p - 1:
        (ta, _), (tb, _) = pipeline.stages[i], pipeline.stages[i + 1]
        if ta != tb:  # cluster boundary: never mix core types in a stage
            i += 1
            continue
        if eq14_merge_helpful(i):
            new_stages = list(pipeline.stages)
            merged = (ta, new_stages[i][1] + new_stages[i + 1][1])
            new_stages[i : i + 2] = [merged]
            pipeline = Pipeline(stages=tuple(new_stages))
            alloc = work_flow(pipeline, layers, T)
            # stay at i: the grown stage may keep absorbing its neighbour
        else:
            i += 1

    # Drop stages that received no layers (their cores stay idle; the
    # paper's final configurations never contain empty stages).
    kept = [
        (st, al)
        for st, al in zip(pipeline.stages, alloc)
        if al
    ]
    pipeline = Pipeline(stages=tuple(st for st, _ in kept))
    alloc = tuple(al for _, al in kept)
    return _plan(pipeline, alloc)


def pipeline_sweep(
    n_layers: int,
    platform: HeteroPlatform,
    T: TimeMatrix,
) -> PipelinePlan:
    """Beyond-paper mode: the number of distinct *pipelines* is small
    (Eq. 1 gives 64 on the 4+4 platform) — the exponential blow-up is in
    the split points, which ``work_flow`` resolves heuristically.  Running
    work_flow on every pipeline is cheap and never worse than Algorithm 3
    (recorded in DESIGN.md §2 / EXPERIMENTS.md §Perf as an improvement)."""
    layers = list(range(n_layers))
    best: Optional[PipelinePlan] = None
    best_tp = -1.0
    h = platform.total_cores()
    for p in range(1, h + 1):
        pipes = (
            enumerate_pipelines(platform, p)
            if p > 1
            else [Pipeline(stages=((ct.name, ct.count),)) for ct in platform.core_types]
        )
        for pipeline in pipes:
            alloc = work_flow(pipeline, layers, T, rule="minmax")
            kept = [(st, al) for st, al in zip(pipeline.stages, alloc) if al]
            plan = _plan(
                Pipeline(stages=tuple(st for st, _ in kept)),
                tuple(al for _, al in kept),
            )
            tp = plan.throughput(T)
            if tp > best_tp:
                best, best_tp = plan, tp
    assert best is not None
    return best


def pipe_it_search(
    n_layers: int,
    platform: HeteroPlatform,
    T: TimeMatrix,
    mode: str = "merge",
) -> PipelinePlan:
    """The Pipe-it DSE entry point (paper §VI).

    mode="merge"  — the paper's Algorithm 3 (faithful).
    mode="sweep"  — beyond-paper work_flow-over-all-pipelines.
    mode="best"   — run both, return the higher-throughput plan.
    """
    if mode == "merge":
        return merge_stage(list(range(n_layers)), platform, T)
    if mode == "sweep":
        return pipeline_sweep(n_layers, platform, T)
    if mode == "best":
        a = merge_stage(list(range(n_layers)), platform, T)
        b = pipeline_sweep(n_layers, platform, T)
        return a if a.throughput(T) >= b.throughput(T) else b
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# Exhaustive reference search (small instances only; used by tests/benches)
# ---------------------------------------------------------------------------

def exhaustive_two_way_split(
    layers: Sequence[int],
    T: TimeMatrix,
    stage_a: StageConfig,
    stage_b: StageConfig,
) -> Tuple[Tuple[Tuple[int, ...], Tuple[int, ...]], float]:
    """Brute-force optimal contiguous two-way split of ``layers``.

    Tries every prefix/suffix cut (the only splits Algorithm 1 can emit)
    and returns ``((left, right), bottleneck)`` minimising
    ``max(T_left^a, T_right^b)``.  O(n^2); reference oracle for the
    ``find_split`` property tests."""
    ordered = list(layers)
    best: Optional[Tuple[Tuple[int, ...], Tuple[int, ...]]] = None
    best_t = float("inf")
    for k in range(len(ordered) + 1):
        left, right = tuple(ordered[:k]), tuple(ordered[k:])
        t = max(stage_time(T, left, stage_a), stage_time(T, right, stage_b))
        if t < best_t:
            best, best_t = (left, right), t
    assert best is not None
    return best, best_t

def _exhaustive_plan(
    n_layers: int, platform: HeteroPlatform, T: TimeMatrix
) -> PipelinePlan:
    """True optimum over EVERY executable plan on ``platform``: all
    partial-cluster pipelines (``enumerate_pipelines(allow_partial=True)``
    — the closure of what merge/sweep can emit after dropping empty
    stages) x every contiguous non-empty layer split, plus every
    single-stage vocabulary config.  Exponential; the inner oracle of
    :func:`exhaustive_partition` and of small-instance
    :func:`partition_search` shares."""
    best: Optional[PipelinePlan] = None
    best_tp = -1.0
    for stage in platform.stage_vocabulary():  # p = 1: any (ct, c) config
        plan = _plan(Pipeline(stages=(stage,)), (tuple(range(n_layers)),))
        tp = plan.throughput(T)
        if tp > best_tp:
            best, best_tp = plan, tp
    top = min(platform.total_cores(), n_layers)
    for p in range(2, top + 1):
        for pipeline in enumerate_pipelines(platform, p, allow_partial=True):
            for cuts in itertools.combinations(range(1, n_layers), p - 1):
                alloc = contiguous_allocation(cuts, n_layers, p)
                plan = _plan(pipeline, alloc)
                tp = plan.throughput(T)
                if tp > best_tp:
                    best, best_tp = plan, tp
    assert best is not None
    return best


# ---------------------------------------------------------------------------
# Two-level partition DSE: clusters across models, layers within each share
# ---------------------------------------------------------------------------

Share = Tuple[Tuple[str, int], ...]  # ((core_type, count), ...) for one model

#: Relative-shortfall penalty that ranks every SLO-feasible assignment above
#: every infeasible one while keeping infeasible ones ordered by how close
#: they come (best-effort under overload).
SLO_PENALTY = 1e9


def _nonneg_compositions(total: int, parts: int) -> List[Tuple[int, ...]]:
    if parts == 1:
        return [(total,)]
    out = []
    for first in range(total + 1):
        for rest in _nonneg_compositions(total - first, parts - 1):
            out.append((first, *rest))
    return out


def enumerate_shares(platform: HeteroPlatform, n_models: int) -> List[Tuple[Share, ...]]:
    """All ways to partition the platform's clusters into ``n_models``
    disjoint core shares.

    Every core is assigned to some model (the paper never idles silicon
    at the cluster level; a model's *inner* DSE may still leave share
    cores unused) and every model receives at least one core.  Returns,
    per assignment, one ``((core_type, count), ...)`` share per model —
    hashable, zero-count entries elided."""
    if n_models < 1:
        raise ValueError("need >= 1 model")
    if n_models > platform.total_cores():
        raise ValueError(
            f"{n_models} models cannot each get a core on "
            f"{platform.total_cores()}-core {platform.name!r}"
        )
    per_ct = [
        _nonneg_compositions(ct.count, n_models) for ct in platform.core_types
    ]
    names = [ct.name for ct in platform.core_types]
    out: List[Tuple[Share, ...]] = []
    for combo in itertools.product(*per_ct):
        shares = []
        for mi in range(n_models):
            share = tuple(
                (names[ci], combo[ci][mi])
                for ci in range(len(names))
                if combo[ci][mi] > 0
            )
            shares.append(share)
        if all(shares):  # every model got >= 1 core
            out.append(tuple(shares))
    return out


def partition_objective(
    throughputs: Sequence[float],
    weights: Optional[Sequence[float]] = None,
    slo_rates: Optional[Sequence[float]] = None,
    fairness: str = "sum",
) -> float:
    """Aggregate co-serving score for one cluster-share assignment.

    fairness="sum"     — utilitarian: ``sum_m w_m * tp_m``.  Maximises
      machine-wide goodput; right when per-model demand is open-ended.
    fairness="max-min" — egalitarian: ``min_m w_m * tp_m``.  Maximises
      the worst model's (weighted) rate; right when every model must
      sustain comparable demand (set ``w_m = 1/demand_m`` to equalise
      heterogeneous demands).

    Either way, each relative SLO shortfall is charged
    :data:`SLO_PENALTY` in the returned scalar.  The *searches* rank
    assignments lexicographically via :func:`_objective_parts` —
    feasibility first, then least total shortfall, then score — so a
    feasible assignment beats every infeasible one even when throughputs
    are large enough to swamp the finite penalty; this scalar is the
    reported/compared form of that same ordering."""
    score, shortfall = _objective_parts(
        throughputs, weights, slo_rates, fairness
    )
    return score - SLO_PENALTY * shortfall


def _objective_parts(
    throughputs: Sequence[float],
    weights: Optional[Sequence[float]],
    slo_rates: Optional[Sequence[float]],
    fairness: str,
) -> Tuple[float, float]:
    """(score, total relative SLO shortfall) for one assignment."""
    m = len(throughputs)
    ws = list(weights) if weights is not None else [1.0] * m
    slos = list(slo_rates) if slo_rates is not None else [0.0] * m
    if len(ws) != m or len(slos) != m:
        raise ValueError("weights/slo_rates must match throughputs")
    weighted = [w * tp for w, tp in zip(ws, throughputs)]
    if fairness == "sum":
        score = sum(weighted)
    elif fairness == "max-min":
        score = min(weighted)
    else:
        raise ValueError(f"unknown fairness {fairness!r}")
    shortfall = sum(
        max(0.0, 1.0 - tp / slo) for tp, slo in zip(throughputs, slos) if slo > 0.0
    )
    return score, shortfall


@dataclasses.dataclass(frozen=True)
class ModelPlan:
    """One model's slice of a partition: its core share and inner plan."""

    name: str
    share: HeteroPlatform
    plan: PipelinePlan
    throughput: float  # predicted Eq. 12 rate on this model's time matrix

    def notation(self) -> str:
        return f"{self.name}@{self.plan.notation()}"


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """A full co-serving assignment: disjoint shares + per-model plans."""

    assignments: Tuple[ModelPlan, ...]
    objective: float
    feasible: bool  # every model met its SLO throughput floor

    @property
    def names(self) -> List[str]:
        return [a.name for a in self.assignments]

    def __getitem__(self, name: str) -> ModelPlan:
        for a in self.assignments:
            if a.name == name:
                return a
        raise KeyError(name)

    def throughputs(self) -> Dict[str, float]:
        return {a.name: a.throughput for a in self.assignments}

    def plans(self) -> Dict[str, PipelinePlan]:
        return {a.name: a.plan for a in self.assignments}

    def notation(self) -> str:
        return " | ".join(a.notation() for a in self.assignments)


def _search_over_shares(
    names: Sequence[str],
    Ts: Sequence[TimeMatrix],
    platform: HeteroPlatform,
    weights: Sequence[float],
    slo_rates: Sequence[float],
    fairness: str,
    inner,
) -> PartitionPlan:
    """Rank every cluster-share assignment by the aggregate objective.

    ``inner(model_index, share) -> PipelinePlan`` supplies the per-share
    layer search; memoized per (model, share) because the same share
    recurs across many assignments."""
    cache: Dict[Tuple[int, Share], Tuple[HeteroPlatform, PipelinePlan, float]] = {}

    def solve(mi: int, share: Share):
        key = (mi, share)
        if key not in cache:
            sub = platform.subset(dict(share))
            plan = inner(mi, sub)
            cache[key] = (sub, plan, plan.throughput(Ts[mi]))
        return cache[key]

    best: Optional[PartitionPlan] = None
    best_key = None
    for assignment in enumerate_shares(platform, len(names)):
        solved = [solve(mi, share) for mi, share in enumerate(assignment)]
        tps = [tp for _, _, tp in solved]
        score, shortfall = _objective_parts(tps, weights, slo_rates, fairness)
        # lexicographic: feasibility beats any score, then least miss,
        # then score — immune to throughputs outscaling the penalty
        key = (shortfall == 0.0, -shortfall, score)
        if best_key is None or key > best_key:
            best_key = key
            best = PartitionPlan(
                assignments=tuple(
                    ModelPlan(name=nm, share=sub, plan=plan, throughput=tp)
                    for nm, (sub, plan, tp) in zip(names, solved)
                ),
                objective=score - SLO_PENALTY * shortfall,
                feasible=shortfall == 0.0,
            )
    assert best is not None
    return best


def _normalize_instances(
    instances: Mapping[str, TimeMatrix],
    weights: Optional[Mapping[str, float]],
    slo_rates: Optional[Mapping[str, float]],
):
    names = list(instances)
    if not names:
        raise ValueError("need >= 1 model instance")
    # a typo'd model name must not silently drop a weight or SLO floor
    for label, mapping in (("weights", weights), ("slo_rates", slo_rates)):
        unknown = [k for k in (mapping or {}) if k not in instances]
        if unknown:
            raise ValueError(
                f"{label} name unknown models {unknown}; instances are {names}"
            )
    Ts = [instances[nm] for nm in names]
    w = [float((weights or {}).get(nm, 1.0)) for nm in names]
    slo = [float((slo_rates or {}).get(nm, 0.0)) for nm in names]
    return names, Ts, w, slo


def partition_search(
    instances: Mapping[str, TimeMatrix],
    platform: HeteroPlatform,
    *,
    weights: Optional[Mapping[str, float]] = None,
    slo_rates: Optional[Mapping[str, float]] = None,
    mode: str = "best",
    exact_threshold: int = 8,
    fairness: str = "sum",
) -> PartitionPlan:
    """Two-level DSE for multi-model co-serving.

    Level 1 enumerates cluster-share assignments (exact — the space is
    small, Eq. 1-style counting over models instead of stages); level 2
    reuses :func:`pipe_it_search` to balance each model's layers within
    its share.  Models whose layer count is <= ``exact_threshold`` also
    get the exhaustive inner search (cheap at that size), so on small
    instances the result provably matches :func:`exhaustive_partition`.

    ``instances`` maps model name -> that model's time matrix (order
    defines model order); ``weights``/``slo_rates``/``fairness`` feed
    :func:`partition_objective`.
    """
    names, Ts, w, slo = _normalize_instances(instances, weights, slo_rates)

    def inner(mi: int, sub: HeteroPlatform) -> PipelinePlan:
        n = len(Ts[mi])
        plan = pipe_it_search(n, sub, Ts[mi], mode=mode)
        if n <= exact_threshold:
            exact = _exhaustive_plan(n, sub, Ts[mi])
            if exact.throughput(Ts[mi]) > plan.throughput(Ts[mi]):
                plan = exact
        return plan

    return _search_over_shares(names, Ts, platform, w, slo, fairness, inner)


def exhaustive_partition(
    instances: Mapping[str, TimeMatrix],
    platform: HeteroPlatform,
    *,
    weights: Optional[Mapping[str, float]] = None,
    slo_rates: Optional[Mapping[str, float]] = None,
    fairness: str = "sum",
) -> PartitionPlan:
    """Oracle for :func:`partition_search`: the same exact share
    enumeration, but with the exhaustive inner search everywhere.
    Exponential in layer count; small instances only (tests/benches)."""
    names, Ts, w, slo = _normalize_instances(instances, weights, slo_rates)

    def inner(mi: int, sub: HeteroPlatform) -> PipelinePlan:
        return _exhaustive_plan(len(Ts[mi]), sub, Ts[mi])

    return _search_over_shares(names, Ts, platform, w, slo, fairness, inner)


def exhaustive_search(
    n_layers: int,
    platform: HeteroPlatform,
    T: TimeMatrix,
    max_stages: Optional[int] = None,
) -> PipelinePlan:
    """Brute-force over every pipeline (Eq. 1) and every contiguous split
    (Eq. 2).  Exponential; only for validating the heuristic."""
    best: Optional[PipelinePlan] = None
    best_tp = -1.0
    h = platform.total_cores()
    top = min(max_stages or h, h, n_layers)
    for p in range(1, top + 1):
        if p == 1:
            # Degenerate single-stage "pipelines": best homogeneous cluster.
            for ct in platform.core_types:
                plan = _plan(
                    Pipeline(stages=((ct.name, ct.count),)),
                    (tuple(range(n_layers)),),
                )
                tp = plan.throughput(T)
                if tp > best_tp:
                    best, best_tp = plan, tp
            continue
        for pipeline in enumerate_pipelines(platform, p):
            for cuts in itertools.combinations(range(1, n_layers), p - 1):
                alloc = contiguous_allocation(cuts, n_layers, p)
                plan = _plan(pipeline, alloc)
                tp = plan.throughput(T)
                if tp > best_tp:
                    best, best_tp = plan, tp
    assert best is not None
    return best
