"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 100 --batch 8 --seq 128 [--reduced] [--ckpt-dir ckpts]

On this CPU container use --reduced (the smoke-scale variant); the full
configs are exercised through the dry-run.  With multiple devices the
production mesh shardings apply automatically.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    import jax

    from ..configs import get_config
    from ..data import make_batch_iterator
    from ..models import init_params
    from ..optim import adamw_init
    from .steps import make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} vocab={cfg.vocab_size}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M")
    opt = adamw_init(params)
    step_fn = jax.jit(
        make_train_step(cfg, None, base_lr=args.lr, warmup=20, total=args.steps),
        donate_argnums=(0, 1),
    )
    it = make_batch_iterator(cfg, args.batch, args.seq, prefetch=2)

    t0 = time.perf_counter()
    tokens_done = 0
    for step in range(1, args.steps + 1):
        batch = next(it)
        params, opt, metrics = step_fn(params, opt, batch)
        tokens_done += args.batch * args.seq
        if step % args.log_every == 0 or step == 1:
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            print(
                f"step {step:5d}  loss {loss:7.4f}  lr {float(metrics['lr']):.2e}  "
                f"gnorm {float(metrics['grad_norm']):.2f}  "
                f"{tokens_done/dt:,.0f} tok/s"
            )
        if args.ckpt_dir and step % args.ckpt_every == 0:
            from ..checkpoint import save_checkpoint

            path = save_checkpoint(
                args.ckpt_dir, step, {"params": params},
                metadata={"arch": cfg.name, "loss": float(metrics["loss"])},
            )
            print(f"  checkpoint -> {path}")
    print(f"done in {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
