"""Sharding rules: parameter, optimizer, batch and cache PartitionSpecs.

Strategy (DESIGN.md §4): tensor-parallel over "model" on the natural axis
(heads / ffn hidden / experts / vocab) PLUS FSDP-style sharding of the
complementary big axis over "data" — XLA inserts the FSDP all-gathers.
``_shard_if_divisible`` degrades any non-divisible dim to replication
(e.g. hymba's 25 heads, smollm's 15 heads, kv=8 on a 16-way model axis).

Batch shards over ("pod", "data"); decode caches shard their *sequence*
dim over "model" (flash-decoding combine in blocks._seqsharded_decode).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from .mesh import batch_axes


def _div(mesh, axis: Optional[str], size: int) -> Optional[str]:
    """axis if size divides evenly over it, else None (replicate)."""
    if axis is None or axis not in mesh.axis_names:
        return None
    return axis if size % mesh.shape[axis] == 0 else None


def _bdiv(mesh, size: int):
    """batch axes tuple if divisible over their product, else None."""
    ax = batch_axes(mesh)
    if not ax:
        return None
    total = 1
    for a in ax:
        total *= mesh.shape[a]
    return ax if size % total == 0 else None


def _leaf_spec(mesh, cfg: ModelConfig, path: str, leaf) -> P:
    shape = leaf.shape
    d = lambda i, ax: _div(mesh, ax, shape[i])

    def spec(*axes):
        return P(*axes)

    name = path.split("/")[-1]
    if name == "embed":
        if cfg.n_codebooks:  # [K, V, D]
            return spec(None, d(1, "model"), d(2, "data"))
        return spec(d(0, "model"), d(1, "data"))  # [V, D]
    if name == "lm_head":
        return spec(d(0, "data"), d(1, "model"))  # [D, V]
    if name == "heads":
        return spec(None, d(1, "data"), d(2, "model"))  # [K, D, V]
    if name in ("vision_proj", "meta_tokens"):
        return spec(None, None)
    if name in ("wq", "wk", "wv"):  # [D, H, dh]
        return spec(d(0, "data"), d(1, "model"), None)
    if name == "wo":  # [H, dh, D]
        return spec(d(0, "model"), None, d(2, "data"))
    if name in ("bq", "bk", "bv"):  # [H, dh]
        return spec(d(0, "model"), None)
    if name in ("w1", "w3"):
        if len(shape) == 3:  # moe experts [E, D, F]
            return spec(d(0, "model"), d(1, "data"), None)
        return spec(d(0, "data"), d(1, "model"))  # [D, F]
    if name == "w2":
        if len(shape) == 3:  # [E, F, D]
            return spec(d(0, "model"), None, d(2, "data"))
        return spec(d(0, "model"), d(1, "data"))  # [F, D]
    if name == "b1":  # [F]
        return spec(d(0, "model"))
    if name == "router":
        return spec(None, None)
    # mamba
    if name == "w_in":  # [D, 2*dI]
        return spec(d(0, "data"), d(1, "model"))
    if name in ("conv_w",):  # [K, dI]
        return spec(None, d(1, "model"))
    if name in ("conv_b", "D_skip"):  # [dI]
        return spec(d(0, "model"))
    if name in ("B_proj", "C_proj", "dt_proj"):  # [dI, *]
        return spec(d(0, "model"), None)
    if name == "w_out":  # [dI or D, D]
        return spec(d(0, "model"), d(1, "data"))
    # mlstm: q/k stay model-replicated (their dh is the SSD contraction
    # dim N — sharding it forces an all-reduce on the big scores tensor);
    # v's dh is the P dim, which flows through the SSD with no contraction
    # => clean model-parallel axis (EXPERIMENTS §Perf H2)
    if name in ("wq_m", "wk_m", "wv_m"):
        # measured: sharding v's P dim over 'model' pushed reshards into
        # the SSD inner scans (collective 858 -> 1264 ms — refuted,
        # EXPERIMENTS §Perf H2 iter 3); mLSTM stays model-replicated
        return spec(d(0, "data"), None, None)
    if name == "w_gates":  # [D, 2H]
        return spec(d(0, "data"), None)
    if name == "w_o_gate":  # [D, D]
        return spec(d(0, "data"), d(1, "model"))
    # slstm wx [D, H, 4dh]: model-REPLICATED on purpose — any model-axis
    # sharding of the sLSTM propagates into its per-timestep recurrent
    # einsum and the partitioner reshards every one of the S scan steps
    # (measured: 29 GB/chip of all-gathers; EXPERIMENTS §Perf H2).  The
    # sLSTM is a small minority of layers (1 per 8 in xLSTM[7:1]); its
    # compute runs model-replicated, data-sharded.
    if name == "wx":
        return spec(d(0, "data"), None, None)
    return P()  # norms, small biases, r, gates: replicate


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(cfg: ModelConfig, params_abs, mesh):
    """PartitionSpec pytree for the parameters (stacked-layer axes get an
    extra leading None automatically: stacked leaves have one more dim than
    the per-layer init, detected by rule shape mismatch is avoided by
    matching on the trailing dims)."""

    def one(path, leaf):
        ps = _path_str(path)
        name = ps.split("/")[-1]
        # leaves under groups/ are stacked with a leading layer axis
        stacked = "/groups/" in f"/{ps}/" or ps.startswith("groups/")
        if stacked:
            sub = jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)
            inner = _leaf_spec(mesh, cfg, ps, sub)
            return P(None, *inner)
        return _leaf_spec(mesh, cfg, ps, leaf)

    return jax.tree_util.tree_map_with_path(one, params_abs)


def opt_specs(cfg: ModelConfig, opt_abs, pspecs):
    """AdamW moments shard like their parameters; step is replicated."""
    from ..optim.adamw import AdamWState

    return AdamWState(step=P(), m=pspecs, v=pspecs)


def batch_specs(cfg: ModelConfig, batch_abs, mesh):
    def one(path, leaf):
        b = _bdiv(mesh, leaf.shape[0])
        return P(b, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(one, batch_abs)


def cache_specs(cfg: ModelConfig, cache_abs, mesh):
    """Decode caches: [L, B, W, kv, dh] -> (None, batch, model(seq), ...).

    Sequence-dim model sharding is what makes 32k/500k caches fit; the
    decode path combines partial softmax stats across the model axis.
    """

    def one(path, leaf):
        ps = _path_str(path)
        name = ps.split("/")[-1]
        shape = leaf.shape
        if name in ("k", "v"):  # [L, B, W, kv, dh]
            return P(
                None, _bdiv(mesh, shape[1]), _div(mesh, "model", shape[2]), None, None
            )
        if name == "pos":  # [L, W]
            return P(None, _div(mesh, "model", shape[1]))
        if name in ("k_scale", "v_scale"):  # [L, B, W, kv]
            return P(
                None, _bdiv(mesh, shape[1]), _div(mesh, "model", shape[2]), None
            )
        # ssm / xlstm states: shard batch; shard the largest trailing dim
        # over model when divisible (ties broken toward the LAST dim — for
        # mLSTM h [L,B,H,N,P] that is P, the contraction-free dim)
        if leaf.ndim >= 3:
            rest = [None] * (leaf.ndim - 2)
            big = max(range(2, leaf.ndim), key=lambda i: (shape[i], i))
            ax = _div(mesh, "model", shape[big])
            rest[big - 2] = ax
            return P(None, _bdiv(mesh, shape[1]), *rest)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(one, cache_abs)


def to_named(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
