"""Serving launcher: batched prefill + decode loop for any architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --reduced --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..models import init_cache, init_params, prefill, serve_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = jax.random.PRNGKey(0)
    params = init_params(cfg, rng)
    b = args.batch
    extra = (cfg.n_patches or 0) + (128 if cfg.block_kind == "hymba" else 0)
    shape = (
        (b, args.prompt_len, cfg.n_codebooks) if cfg.n_codebooks else (b, args.prompt_len)
    )
    prompt = jax.random.randint(rng, shape, 0, cfg.vocab_size)
    batch = {"tokens": prompt}
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(rng, (b, cfg.n_patches, 1152))

    caches = init_cache(cfg, b, max_len=args.prompt_len + extra + args.gen)
    t0 = time.perf_counter()
    _, caches = jax.jit(lambda p, bt, c: prefill(cfg, p, bt, c))(params, batch, caches)
    jax.block_until_ready(caches)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {b}x{args.prompt_len} in {t_prefill*1e3:.0f}ms")

    step = jax.jit(
        lambda p, c, t, pos: serve_step(cfg, p, c, t, pos), donate_argnums=(1,)
    )
    tok = prompt[:, -1:]
    t0 = time.perf_counter()
    generated = []
    for i in range(args.gen):
        pos = args.prompt_len + extra + i
        logits, caches = step(params, caches, tok, jnp.int32(pos))
        nxt = jnp.argmax(logits, axis=-1)
        tok = nxt[:, None, :] if cfg.n_codebooks else nxt[:, None]
        generated.append(nxt)
    jax.block_until_ready(generated)
    dt = time.perf_counter() - t0
    print(
        f"decode: {args.gen} steps x batch {b} = {args.gen*b} tokens "
        f"in {dt*1e3:.0f}ms -> {args.gen*b/dt:,.1f} tok/s"
    )
    print("sample token ids:", [int(g[0]) if g[0].ndim == 0 else g[0].tolist() for g in generated[:8]])


if __name__ == "__main__":
    main()
