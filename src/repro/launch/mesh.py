"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax use).

Production target: TPU v5e, 256 chips per pod in a (16, 16) (data, model)
mesh; the multi-pod variant adds a leading "pod" axis over 2 pods = 512
chips.  Batch is sharded over ("pod", "data"); weights/experts/heads over
"model" (see launch/shardings.py).
"""
from __future__ import annotations

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = 1):
    """Small mesh for CPU tests (model*data must be <= available devices)."""
    return make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
