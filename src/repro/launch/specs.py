"""input_specs: ShapeDtypeStruct stand-ins for every model input, per
(architecture x input shape) — weak-type-correct, shardable, no device
allocation (the shannon/kernels pattern).

Modality frontends are STUBS per the assignment: paligemma gets 256
precomputed 1152-d SigLIP patch embeddings; musicgen gets 4 parallel
EnCodec codebook token streams.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.shapes import InputShape
from ..models import init_cache
from ..models.config import ModelConfig
from ..models.model import N_META_TOKENS, SIGLIP_DIM


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.n_codebooks:
        tok = jax.ShapeDtypeStruct((b, s, cfg.n_codebooks), jnp.int32)
        return {"tokens": tok, "labels": tok}
    if cfg.n_patches:
        # image patches are part of the sequence budget: text = s - patches
        st = s - cfg.n_patches
        return {
            "tokens": jax.ShapeDtypeStruct((b, st), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, st), jnp.int32),
            "patches": jax.ShapeDtypeStruct((b, cfg.n_patches, SIGLIP_DIM), jnp.float32),
        }
    if cfg.block_kind == "hymba":
        # meta tokens are prepended inside the model; keep total = s
        st = s - N_META_TOKENS
        tok = jax.ShapeDtypeStruct((b, st), jnp.int32)
        return {"tokens": tok, "labels": tok}
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return {"tokens": tok, "labels": tok}


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    specs = train_batch_specs(cfg, shape)
    specs.pop("labels")
    return specs


def cache_abstract(cfg: ModelConfig, shape: InputShape):
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, max_len=shape.seq_len)
    )


def decode_specs(cfg: ModelConfig, shape: InputShape):
    b = shape.global_batch
    if cfg.n_codebooks:
        tok = jax.ShapeDtypeStruct((b, 1, cfg.n_codebooks), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache_abstract(cfg, shape), tok, pos


def input_specs(cfg: ModelConfig, shape: InputShape):
    """Returns (kind, specs...) matching the step function for this shape."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {
            "batch": prefill_batch_specs(cfg, shape),
            "caches": cache_abstract(cfg, shape),
        }
    caches, tok, pos = decode_specs(cfg, shape)
    return {"caches": caches, "tokens": tok, "pos": pos}
