import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks device count on first init).
# The 512 placeholder host devices exist ONLY for this dry-run entry point;
# tests and benchmarks see the real single CPU device.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape), lower + compile the appropriate
step (train_step / prefill_step / serve_step) against ShapeDtypeStruct
inputs on the production mesh — single-pod (16, 16) = 256 chips and
multi-pod (2, 16, 16) = 512 chips — then record memory_analysis,
cost_analysis and the collective schedule for EXPERIMENTS.md §Dry-run /
§Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import sys
import time
import traceback


def run_one(arch: str, shape_name: str, multi_pod: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from ..configs import SHAPES, get_config
    from ..models import MeshCtx, abstract_params
    from ..optim import adamw_init
    from ..roofline.analysis import analyze_compiled, count_params, model_flops
    from .mesh import batch_axes, make_production_mesh
    from .shardings import (
        batch_specs,
        cache_specs,
        opt_specs,
        param_specs,
        to_named,
    )
    from .specs import input_specs
    from .steps import make_prefill_step, make_serve_step, make_train_step

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.supports_long_context():
        return {
            "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "status": "skipped",
            "reason": "pure full attention — long_500k requires sub-quadratic decode (DESIGN.md §4)",
        }

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    bax = batch_axes(mesh)
    n_batch_shards = 1
    for a in bax:
        n_batch_shards *= mesh.shape[a]
    ctx = MeshCtx(
        mesh=mesh, batch_axes=bax,
        shard_batch=shape.global_batch % n_batch_shards == 0,
    )

    params_abs = abstract_params(cfg)
    pspecs = param_specs(cfg, params_abs, mesh)
    specs = input_specs(cfg, shape)
    t0 = time.perf_counter()

    with mesh:
        if shape.kind == "train":
            step = make_train_step(cfg, ctx)
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            ospecs = opt_specs(cfg, opt_abs, pspecs)
            bspecs = batch_specs(cfg, specs["batch"], mesh)
            jitted = jax.jit(
                step,
                in_shardings=(to_named(pspecs, mesh), to_named(ospecs, mesh),
                              to_named(bspecs, mesh)),
                out_shardings=(to_named(pspecs, mesh), to_named(ospecs, mesh), None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_abs, opt_abs, specs["batch"])
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, ctx)
            bspecs = batch_specs(cfg, specs["batch"], mesh)
            cspecs = cache_specs(cfg, specs["caches"], mesh)
            jitted = jax.jit(
                step,
                in_shardings=(to_named(pspecs, mesh), to_named(bspecs, mesh),
                              to_named(cspecs, mesh)),
                out_shardings=(None, to_named(cspecs, mesh)),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params_abs, specs["batch"], specs["caches"])
        else:  # decode
            step = make_serve_step(cfg, ctx)
            cspecs = cache_specs(cfg, specs["caches"], mesh)
            tspec = batch_specs(cfg, {"tokens": specs["tokens"]}, mesh)["tokens"]
            jitted = jax.jit(
                step,
                in_shardings=(to_named(pspecs, mesh), to_named(cspecs, mesh),
                              to_named(tspec, mesh), None),
                out_shardings=(None, to_named(cspecs, mesh)),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                params_abs, specs["caches"], specs["tokens"], specs["pos"]
            )
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    print(f"[{arch} x {shape_name} | {'2x16x16' if multi_pod else '16x16'}] "
          f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
    print(f"  memory_analysis: {mem}")  # proves it fits
    terms = analyze_compiled(compiled, n_chips)
    mf = model_flops(cfg, params_abs, shape)
    terms.finalize(mf)
    ca = compiled.cost_analysis() or {}
    print(f"  cost_analysis: flops/chip={terms.flops_per_chip:.3e} "
          f"bytes/chip={terms.bytes_per_chip:.3e}")
    print(f"  roofline: compute={terms.compute_s*1e3:.2f}ms "
          f"memory={terms.memory_s*1e3:.2f}ms "
          f"collective={terms.collective_s*1e3:.2f}ms "
          f"-> {terms.bottleneck}-bound; useful_ratio={terms.useful_ratio:.3f}")

    total, active = count_params(get_config(arch), params_abs)
    return {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "params_total": total,
        "params_active": active,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes if mem else None,
            "output_bytes": mem.output_size_in_bytes if mem else None,
            "temp_bytes": mem.temp_size_in_bytes if mem else None,
            "alias_bytes": mem.alias_size_in_bytes if mem else None,
            "per_chip_gb": terms.memory_per_chip_gb,
        },
        "roofline": terms.to_dict(),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="every arch x shape x mesh")
    ap.add_argument("--out", default=None, help="append JSON lines here")
    args = ap.parse_args()

    from ..configs import ARCHS, SHAPES

    combos = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                combos.append((arch, shape, False))
                combos.append((arch, shape, True))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        combos = [(args.arch, args.shape, args.multi_pod)]

    failures = 0
    for arch, shape, mp in combos:
        try:
            rec = run_one(arch, shape, mp)
        except Exception as e:
            traceback.print_exc()
            rec = {
                "arch": arch, "shape": shape, "multi_pod": mp,
                "status": "error", "error": f"{type(e).__name__}: {e}",
            }
            failures += 1
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
