"""Step builders: train_step / prefill_step / serve_step closures."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..models import MeshCtx, ModelConfig, loss_fn, prefill, serve_step
from ..optim import adamw_init, adamw_update, cosine_schedule


def make_train_step(cfg: ModelConfig, ctx: Optional[MeshCtx] = None,
                    base_lr: float = 3e-4, warmup: int = 2000, total: int = 100_000):
    accum = max(1, cfg.grad_accum)

    def grad_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, ctx=ctx), has_aux=True
        )(params)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = grad_of(params, batch)
        else:
            # gradient accumulation: scan over microbatches so only one
            # microbatch's activations are live at a time (the lever that
            # fits the 100B-scale train shapes; EXPERIMENTS.md §Perf)
            micro = jax.tree.map(
                lambda a: a.reshape(accum, a.shape[0] // accum, *a.shape[1:]),
                batch,
            )

            def acc_step(carry, mb):
                g_acc, loss_acc = carry
                (loss, metrics), g = grad_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, loss_acc + loss), metrics

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), metrics = jax.lax.scan(
                acc_step, (g0, jnp.float32(0.0)), micro
            )
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        lr = cosine_schedule(opt_state.step, base_lr, warmup, total)
        new_params, new_opt, om = adamw_update(params, grads, opt_state, lr)
        out_metrics = {"loss": loss, **metrics, **om}
        return new_params, new_opt, out_metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, ctx: Optional[MeshCtx] = None):
    def prefill_step(params, batch, caches):
        return prefill(cfg, params, batch, caches, ctx=ctx)

    return prefill_step


def make_serve_step(cfg: ModelConfig, ctx: Optional[MeshCtx] = None):
    def step(params, caches, tokens, pos):
        return serve_step(cfg, params, caches, tokens, pos, ctx=ctx)

    return step
