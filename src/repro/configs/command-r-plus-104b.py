"""command-r-plus-104b — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].

64 layers, d_model=12288, 96 heads (GQA kv=8), d_ff=33792, vocab 256000.
Cohere blocks use parallel attention+FFN residual with a single LayerNorm
and tied embeddings.  Pure full attention => long_500k skipped (DESIGN.md
§4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    arch_type="dense",
    block_kind="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    parallel_residual=True,
    norm="layer",
    use_bias=False,
    tie_embeddings=True,
    rope_theta=75_000_000.0,
    grad_accum=8,
    kv_quant=True,  # int8 KV cache: decode_32k 18.2GB exceeds 16GB otherwise (EXPERIMENTS §Perf H3)  # 256-batch train does not fit otherwise (EXPERIMENTS §Perf)
    source="hf:CohereForAI/c4ai-command-r-v01 (scaled to R+ dims)",
)
