"""moonshot-v1-16b-a3b — Moonlight (kimi), DeepSeek-V3-style MoE
[hf:moonshotai/Moonlight-16B-A3B].

48 layers, d_model=2048, 16 heads (kv=16), per-expert d_ff=1408, vocab
163840, 64 routed experts top-6 with shared experts (16B total / ~3B
active).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    arch_type="moe",
    block_kind="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    first_dense_layers=1,
    grad_accum=4,
    kv_quant=True,  # int8 KV cache: decode_32k 23GB exceeds 16GB otherwise
    source="hf:moonshotai/Moonlight-16B-A3B",
)
