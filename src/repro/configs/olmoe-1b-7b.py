"""olmoe-1b-7b — 64 experts top-8, no shared experts [arXiv:2409.02060].

16 layers, d_model=2048, 16 heads (kv=16), per-expert d_ff=1024, vocab
50304.  OLMoE uses QK-norm and does NOT renormalize top-k router weights.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    block_kind="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    top_k=8,
    n_shared_experts=0,
    first_dense_layers=0,
    qk_norm=True,
    renorm_topk=False,
    grad_accum=2,
    source="arXiv:2409.02060 (OLMoE-1B-7B)",
)
