"""smollm-360m — llama-architecture small model
[hf:HuggingFaceTB/SmolLM-135M family, 360M variant].

32 layers, d_model=960, 15 heads (GQA kv=5), d_ff=2560, vocab 49152.
Pure full attention => long_500k skipped (DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    arch_type="dense",
    block_kind="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-360M",
)
