"""paligemma-3b — SigLIP + gemma decoder [arXiv:2407.07726].

Language/decoder backbone only: 18 layers, d_model=2048, 8 heads (GQA
kv=1, i.e. MQA), d_ff=16384, vocab 257216.  The SigLIP vision tower is a
STUB per the assignment: ``input_specs`` provides 256 precomputed patch
embeddings (1152-d SigLIP features) which the model projects and prepends
with a bidirectional prefix-LM mask.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    arch_type="vlm",
    block_kind="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    n_patches=256,
    act="gelu",
    glu=True,
    tie_embeddings=True,
    grad_accum=2,
    source="arXiv:2407.07726 (PaliGemma-3B / gemma-2b backbone)",
)
