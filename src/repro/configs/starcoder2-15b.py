"""starcoder2-15b — GQA + RoPE + sliding window [arXiv:2402.19173].

40 layers, d_model=6144, 48 heads (GQA kv=4), d_ff=24576, vocab 49152.
StarCoder2 uses a 4096-token sliding window and biases => sub-quadratic
decode state, so long_500k RUNS for this arch (window ring-buffer cache).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    arch_type="dense",
    block_kind="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    sliding_window=4096,
    use_bias=True,
    act="gelu",
    glu=False,
    norm="layer",
    rope_theta=100_000.0,
    grad_accum=4,
    source="arXiv:2402.19173 (StarCoder2-15B)",
)
