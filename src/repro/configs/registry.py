"""Config registry: loads ``<arch-id>.py`` files (ids contain dashes, so
they are loaded by path rather than imported as modules)."""
from __future__ import annotations

import importlib.util
import os
from typing import Dict, List

from ..models.config import ModelConfig

_DIR = os.path.dirname(__file__)

ARCHS: List[str] = [
    "xlstm-1.3b",
    "hymba-1.5b",
    "command-r-plus-104b",
    "deepseek-moe-16b",
    "paligemma-3b",
    "smollm-360m",
    "moonshot-v1-16b-a3b",
    "musicgen-large",
    "olmoe-1b-7b",
    "starcoder2-15b",
]

_cache: Dict[str, ModelConfig] = {}


def get_config(arch: str) -> ModelConfig:
    if arch in _cache:
        return _cache[arch]
    path = os.path.join(_DIR, f"{arch}.py")
    if not os.path.exists(path):
        raise KeyError(f"unknown arch {arch!r}; available: {ARCHS}")
    spec = importlib.util.spec_from_file_location(f"repro_config_{arch}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    cfg = mod.CONFIG
    _cache[arch] = cfg
    return cfg
