"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517].

48 layers, d_model=2048, 4 heads, vocab 50304, d_ff=0 (the xLSTM block's
up-projection lives inside the mLSTM cell; no separate FFN).  The 1.3B
model in the paper is xLSTM[7:1]: one sLSTM block per 8 layers, the rest
mLSTM — expressed here as slstm_every=8.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    block_kind="xlstm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    glu=False,
    tie_embeddings=False,
    grad_accum=4,
    act_shard=False,  # EXPERIMENTS §Perf H2: gathers from act-sharded carries dominate; accum=4 pays the memory instead
    source="arXiv:2405.04517 (xLSTM[7:1] 1.3B)",
)
