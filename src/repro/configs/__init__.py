"""Architecture config registry: ``get_config(arch_id)``."""
from .registry import ARCHS, get_config
from .shapes import SHAPES, InputShape

__all__ = ["ARCHS", "get_config", "SHAPES", "InputShape"]
