"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066].

28 layers, d_model=2048, 16 heads (kv=16, i.e. MHA), per-expert d_ff=1408,
vocab 102400.  The first layer keeps a dense FFN (DeepSeekMoE design);
remaining 27 layers are MoE.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    block_kind="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    first_dense_layers=1,
    grad_accum=4,
    source="arXiv:2401.06066 (DeepSeekMoE 16B)",
)
