"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

Transformer backbone only: 48 layers, d_model=2048, 32 heads (kv=32, MHA),
d_ff=8192, vocab 2048 per codebook.  The EnCodec audio codec is a STUB per
the assignment: inputs are 4 parallel codebook token streams (delay
pattern applied upstream); embeddings are summed, and 4 output heads
predict the next token of each codebook.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    block_kind="dense",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    n_codebooks=4,
    act="gelu",
    glu=False,
    norm="layer",
    use_bias=True,
    grad_accum=2,
    kv_quant=True,  # int8 KV cache: full-MHA decode_32k cache 23GB otherwise
    source="arXiv:2306.05284 (MusicGen-large)",
)
