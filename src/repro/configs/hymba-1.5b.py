"""hymba-1.5b — parallel attention + mamba heads [arXiv:2411.13676].

32 layers, d_model=1600, 25 heads (GQA kv=5), d_ff=5504, vocab 32001,
ssm_state=16.  Hymba uses sliding-window attention everywhere except the
first, middle and last layers (full attention), plus 128 learnable meta
tokens prepended to every sequence.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    block_kind="hymba",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    conv_kernel=4,
    ssd_chunk=64,  # halves SSD score traffic (EXPERIMENTS §Perf H4)
    sliding_window=1024,
    full_attn_layers=(0, 16, 31),
    grad_accum=2,
    source="arXiv:2411.13676 (Hymba-1.5B)",
)
