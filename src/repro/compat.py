"""Cross-version jax compatibility shims (jax 0.4.x through 0.7.x).

The repo targets current jax APIs, but the tier-1 container pins an older
release.  Two surfaces moved:

* ``jax.sharding.AxisType`` / ``jax.make_mesh(axis_types=...)`` — absent
  before 0.5; meshes there are implicitly all-Auto, which is what we
  request anyway.
* ``jax.shard_map(..., check_vma=...)`` — older releases ship it as
  ``jax.experimental.shard_map.shard_map(..., check_rep=...)`` (same
  replication check, earlier name).

Import :func:`make_mesh` and :func:`shard_map` from here instead of using
the jax namespaces directly.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def make_mesh(shape, axes):
    """``jax.make_mesh`` with all-Auto axis types where supported."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def axis_size(axis_name):
    """Static mesh-axis size inside shard_map, on any jax version.

    ``jax.lax.axis_size`` is recent; on older releases ``psum(1, name)``
    folds to a concrete Python int at trace time.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )

else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma,
        )
