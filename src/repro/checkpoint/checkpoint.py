"""Sharding-aware .npz checkpointing with metadata.

Layout: <dir>/step_<N>/arrays.npz + manifest.json.  Pytree structure is
flattened to path-keyed arrays; on restore the arrays are device_put with
the caller's shardings (so a checkpoint written on one mesh restores onto
another — the resharding is just a different device_put).  Writes are
atomic (tmp dir + rename) and a `latest` symlink tracks the newest step.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            # npz cannot store bf16; f32 holds it losslessly (the manifest
            # records the original dtype and restore casts back)
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Any, metadata: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    latest = os.path.join(directory, "latest")
    if os.path.islink(latest):
        os.unlink(latest)
    os.symlink(os.path.basename(final), latest)
    return final


def load_checkpoint(directory: str, step: Optional[int] = None) -> Tuple[Dict[str, np.ndarray], dict]:
    path = (
        os.path.join(directory, f"step_{step:08d}")
        if step is not None
        else os.path.join(directory, "latest")
    )
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = dict(np.load(os.path.join(path, "arrays.npz")))
    return arrays, manifest


def restore_sharded(directory: str, target_tree: Any, shardings: Optional[Any] = None,
                    step: Optional[int] = None) -> Any:
    """Restore into the structure of ``target_tree`` (a pytree of arrays or
    ShapeDtypeStructs), placing each leaf with the matching sharding."""
    arrays, _ = load_checkpoint(directory, step)
    paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves = []
    shard_leaves = (
        jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "addressable_devices")
        )
        if shardings is not None
        else [None] * len(paths)
    )
    for (path, leaf), sh in zip(paths, shard_leaves):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != target {leaf.shape}")
        arr = jnp.asarray(arr).astype(leaf.dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
